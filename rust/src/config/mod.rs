//! Experiment configuration: a typed schema with JSON loading, presets for
//! the paper's two workloads, validation, and `key=value` overrides (the
//! CLI accepts `--set hfl.devices=20`).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Which dataset/model pair an experiment trains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dataset {
    Mnist,
    Cifar,
}

impl Dataset {
    pub fn name(self) -> &'static str {
        match self {
            Dataset::Mnist => "mnist",
            Dataset::Cifar => "cifar",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "mnist" => Ok(Dataset::Mnist),
            "cifar" => Ok(Dataset::Cifar),
            _ => bail!("unknown dataset '{s}' (expected mnist|cifar)"),
        }
    }

    /// Input tensor shape [H, W, C].
    pub fn input_shape(self) -> [usize; 3] {
        match self {
            Dataset::Mnist => [28, 28, 1],
            Dataset::Cifar => [32, 32, 3],
        }
    }

    pub fn classes(self) -> usize {
        10
    }
}

/// Data-distribution regimes of paper §4.5 / Fig. 10.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Partition {
    Iid,
    /// Each device holds `labels` distinct classes (paper default: 2).
    LabelSkew { labels: usize },
    /// Dirichlet(alpha) class mixture per device (paper: alpha = 0.5).
    Dirichlet { alpha: f64 },
}

impl Partition {
    pub fn describe(&self) -> String {
        match self {
            Partition::Iid => "iid".into(),
            Partition::LabelSkew { labels } => format!("label{labels}"),
            Partition::Dirichlet { alpha } => format!("dirichlet{alpha}"),
        }
    }
}

/// Device population & topology (paper §4.1: 50 devices, 5 edges; 3 edges /
/// 30 devices in CN, 2 edges / 20 devices in US).
#[derive(Clone, Debug)]
pub struct TopologyConfig {
    pub devices: usize,
    pub edges: usize,
    /// Fraction of devices (and edges) in the "cn" region; the rest "us".
    pub cn_fraction: f64,
    /// Max devices a single edge aggregation supports (artifact Nmax).
    pub nmax: usize,
}

/// HFL training setup.
#[derive(Clone, Debug)]
pub struct HflConfig {
    pub dataset: Dataset,
    pub partition: Partition,
    /// Samples held by each device (must be nb*batch of the artifacts).
    pub samples_per_device: usize,
    /// Simulated-seconds training budget T (paper: 3000 MNIST / 12000 CIFAR).
    pub threshold_time: f64,
    /// Default frequencies for fixed-frequency baselines.
    pub gamma1: usize,
    pub gamma2: usize,
    /// Upper bounds of the agent's action space.
    pub gamma1_max: usize,
    pub gamma2_max: usize,
}

/// DRL agent hyper-parameters (paper §4.1).
#[derive(Clone, Debug)]
pub struct AgentConfig {
    pub episodes: usize,
    /// Reward base Υ (paper: 64).
    pub upsilon: f64,
    /// Energy weight ε (paper: 0.002 MNIST / 0.03 CIFAR).
    pub epsilon: f64,
    /// Discount ξ and GAE smoothing λ (paper: 0.9 / 0.9).
    pub xi: f64,
    pub lambda: f64,
    /// PPO epochs per episode batch.
    pub update_epochs: usize,
    /// Max trajectory rounds per episode (artifact traj_batch).
    pub traj_max: usize,
    pub npca: usize,
}

/// Which synchronization engine schedule executes the hierarchy
/// (`hfl::async_engine::SyncMode` is built from this).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SyncModeCfg {
    /// Barrier-synchronized rounds (the paper's setting; default).
    Synchronous,
    /// Edges aggregate on a K-quorum of reports; cloud on a timer.
    SemiSync,
    /// Staleness-discounted fully asynchronous aggregation.
    Async,
}

impl SyncModeCfg {
    pub fn name(self) -> &'static str {
        match self {
            SyncModeCfg::Synchronous => "sync",
            SyncModeCfg::SemiSync => "semi-sync",
            SyncModeCfg::Async => "async",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "sync" | "synchronous" => Ok(SyncModeCfg::Synchronous),
            "semi-sync" | "semisync" | "semi" => Ok(SyncModeCfg::SemiSync),
            "async" => Ok(SyncModeCfg::Async),
            _ => bail!("unknown sync mode '{s}' (sync|semi-sync|async)"),
        }
    }
}

/// Knobs of the event-driven synchronization modes.
#[derive(Clone, Debug)]
pub struct SyncConfig {
    pub mode: SyncModeCfg,
    /// SemiSync: device reports that close an edge round (0 = all active
    /// members, i.e. synchronous-per-edge behavior).
    pub quorum: usize,
    /// Async: staleness discount exponent α of 1/(1+s)^α (0 disables).
    /// The uniform default every edge starts from; with `learned` on, the
    /// agent re-arms per-edge α_j inside `[alpha_min, alpha_max]`.
    pub staleness_alpha: f64,
    /// SemiSync/Async: cloud aggregation timer period, simulated seconds.
    pub cloud_interval: f64,
    /// Drive the event engine with the trained per-edge controller: the
    /// DRL agent re-arms (γ1_j, α_j) at every cloud decision point
    /// instead of holding the fixed `hfl.gamma1`/`staleness_alpha` knobs
    /// (`arena run --scheme arena-async`, harness `fig_async_headtohead`).
    pub learned: bool,
    /// Per-edge decode bounds of the learned staleness exponent α_j.
    pub alpha_min: f64,
    pub alpha_max: f64,
}

impl Default for SyncConfig {
    fn default() -> Self {
        SyncConfig {
            mode: SyncModeCfg::Synchronous,
            quorum: 2,
            staleness_alpha: 0.5,
            cloud_interval: 150.0,
            learned: false,
            alpha_min: 0.0,
            alpha_max: 2.0,
        }
    }
}

/// Knobs of the membership subsystem (`hfl::membership`): churn-driven
/// re-clustering of the live population (paper §3.1 "periodically
/// re-cluster").
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Drift fraction that triggers a re-cluster: joins+leaves since the
    /// last clustering divided by the population, or the relative live
    /// edge-size imbalance (worst region), whichever is larger. `<= 0`
    /// disables re-clustering entirely (the pre-subsystem behavior;
    /// default).
    pub recluster_threshold: f64,
    /// Minimum simulated seconds between re-clusterings (profiling the
    /// whole population is not free; this rate-limits it).
    pub recluster_min_interval: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            recluster_threshold: 0.0,
            recluster_min_interval: 300.0,
        }
    }
}

/// Knobs of the client-lifecycle subsystem (`hfl::lifecycle`):
/// over-selection with straggler abandonment and diurnal pace steering
/// ("Towards Federated Learning at Scale", arXiv:1902.01046). Defaults
/// are inert: the engines behave exactly as before the subsystem landed.
#[derive(Clone, Debug)]
pub struct LifecycleConfig {
    /// Over-selection factor: each edge dispatches
    /// `ceil(K * overselect)` devices (K = the edge's quorum target)
    /// and closes its round on the first K landings, voiding the
    /// stragglers through the stale-result path. `0` disables (every
    /// active member is dispatched and none are abandoned); enabled
    /// values must be `>= 1` (Google's 130% is `1.3`).
    pub overselect: f64,
    /// Diurnal day length in simulated seconds for pace steering:
    /// devices carry seeded availability windows and dispatches outside
    /// a device's window are deferred to its next window start (arrival
    /// shaping, never a stall). `0` disables.
    pub pace_day: f64,
    /// Mean fraction of the day each device is available.
    pub avail_frac: f64,
}

impl Default for LifecycleConfig {
    fn default() -> Self {
        LifecycleConfig {
            overselect: 0.0,
            pace_day: 0.0,
            avail_frac: 0.5,
        }
    }
}

/// Knobs of deterministic failure injection (`hfl::lifecycle::FaultPlan`):
/// event counts are drawn over the run horizon from a dedicated seeded
/// stream and land as first-class scheduled `Event`s. All counts default
/// to 0 — a zero-fault plan schedules nothing, so the fault layer is
/// bitwise invisible when disabled (the sixth determinism guarantee).
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Edge-server outages over the run (each picks a seeded edge+time).
    pub outages: usize,
    /// Seconds a downed edge stays down before recovering.
    pub outage_duration: f64,
    /// Edge↔cloud network partitions over the run (each severs a seeded
    /// bitmask of edges).
    pub partitions: usize,
    /// Seconds a partition lasts before healing.
    pub partition_duration: f64,
    /// Mid-round device crash/rejoin storms over the run.
    pub crash_storms: usize,
    /// Fraction of devices hit by each crash storm.
    pub crash_frac: f64,
    /// Seconds until a storm's crashed devices rejoin.
    pub rejoin_delay: f64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            outages: 0,
            outage_duration: 120.0,
            partitions: 0,
            partition_duration: 180.0,
            crash_storms: 0,
            crash_frac: 0.3,
            rejoin_delay: 90.0,
        }
    }
}

/// Knobs of the edge↔cloud transfer layer (`sim::link`). Bandwidth scales
/// multiply the region bandwidth of `SimConfig` per direction, so uplinks
/// and downlinks can be provisioned asymmetrically (consumer uplinks are
/// typically the narrow side).
#[derive(Clone, Debug)]
pub struct LinkConfig {
    /// Uplink (edge→cloud) bandwidth as a multiple of the region bandwidth.
    pub up_bandwidth_scale: f64,
    /// Downlink (cloud→edge) bandwidth as a multiple of the region bandwidth.
    pub down_bandwidth_scale: f64,
    /// Fair-share contention when multiple transfers overlap on one link
    /// (false = infinite-capacity links, transfers never slow each other).
    pub contention: bool,
}

impl Default for LinkConfig {
    fn default() -> Self {
        LinkConfig {
            up_bandwidth_scale: 1.0,
            down_bandwidth_scale: 1.0,
            contention: true,
        }
    }
}

/// Simulation calibration (Fig. 3 / Fig. 4 models; see sim/).
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Base single-SGD-batch time at zero interference, seconds.
    pub sgd_base_time: f64,
    /// Interference sensitivity κ: time multiplier = 1 + κ·u/(1-u).
    pub cpu_kappa: f64,
    /// Log-normal jitter sigma on per-batch time.
    pub time_jitter: f64,
    /// Device idle->busy power band, watts-equivalent (scaled to mAh).
    pub power_idle: f64,
    pub power_max: f64,
    /// Region comm parameters: [latency_s, bytes_per_s] for cn and us.
    pub cn_latency: f64,
    pub cn_bandwidth: f64,
    pub us_latency: f64,
    pub us_bandwidth: f64,
    /// Jitter sigma on communication time.
    pub comm_jitter: f64,
    /// Device mobility (paper §1): per-round probability an active device
    /// leaves, and a departed one rejoins. Defaults (0 / 1) disable churn.
    pub leave_prob: f64,
    pub join_prob: f64,
    /// Worker threads for the sharded simulation layer and the engines'
    /// parallel device-simulation/materialization paths (0 = available
    /// parallelism, 1 = serial). Execution detail: any value replays the
    /// same run bit-for-bit, so this is deliberately absent from
    /// `to_json` (the run-identity digest).
    pub workers: usize,
    /// Event-queue backend (`auto`/`binary`/`calendar`). Also bitwise
    /// invisible — backends share one total event order — and likewise
    /// excluded from the run-identity digest.
    pub queue_backend: crate::sim::event::QueueBackend,
    /// Per-shard profiler for the parallel runtime: with an observer
    /// attached, shards record event counts, queue depths, wall times
    /// and barrier stalls into `Observer::on_shard_barrier` (and the
    /// engines time their simulation batches). Profiler-on is bitwise
    /// identical to profiler-off — the fifth determinism guarantee —
    /// so this too is excluded from the run-identity digest.
    pub profiler: bool,
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub seed: u64,
    pub topology: TopologyConfig,
    pub hfl: HflConfig,
    pub agent: AgentConfig,
    pub sim: SimConfig,
    pub sync: SyncConfig,
    pub link: LinkConfig,
    pub cluster: ClusterConfig,
    pub lifecycle: LifecycleConfig,
    pub fault: FaultConfig,
    /// Worker threads for parallel device training (0 = auto).
    pub workers: usize,
    /// Run model aggregation natively in rust instead of through the
    /// fedavg_reduce artifact. On CPU the interpret-mode Pallas kernel is
    /// emulated (~80-400x slower than a native loop — see EXPERIMENTS.md
    /// §Perf); on a real TPU backend the artifact is the right path, so
    /// this defaults to false.
    pub native_aggregation: bool,
    /// Directory holding the AOT artifacts.
    pub artifacts_dir: String,
}

impl ExperimentConfig {
    /// Paper-shaped MNIST preset, scaled to the in-repo simulator.
    /// (The paper's testbed: 50 devices / 5 edges; default here is 20 / 5
    /// so full agent trainings fit the 1-core CI box — `--set
    /// topology.devices=50` restores paper scale. EXPERIMENTS.md records
    /// the scaling per experiment.)
    pub fn mnist() -> Self {
        ExperimentConfig {
            seed: 42,
            topology: TopologyConfig {
                devices: 20,
                edges: 5,
                cn_fraction: 0.6,
                nmax: 16,
            },
            hfl: HflConfig {
                dataset: Dataset::Mnist,
                partition: Partition::LabelSkew { labels: 2 },
                samples_per_device: 64, // nb=2 * batch=32
                threshold_time: 3000.0,
                gamma1: 5,
                gamma2: 4,
                gamma1_max: 8,
                gamma2_max: 4,
            },
            agent: AgentConfig {
                episodes: 12,
                upsilon: 64.0,
                epsilon: 0.002,
                xi: 0.9,
                lambda: 0.9,
                update_epochs: 4,
                traj_max: 32,
                npca: 6,
            },
            sim: SimConfig {
                // Calibrated so ~10-15 cloud rounds fit in T=3000s with the
                // paper's gamma1*gamma2=20 (Raspberry-Pi-class speeds).
                sgd_base_time: 2.0,
                cpu_kappa: 1.2,
                time_jitter: 0.18,
                power_idle: 2.2,
                power_max: 6.2,
                cn_latency: 0.9,
                cn_bandwidth: 1.8e6,
                us_latency: 0.12,
                us_bandwidth: 9.0e6,
                comm_jitter: 0.15,
                leave_prob: 0.0,
                join_prob: 1.0,
                workers: 1,
                queue_backend: crate::sim::event::QueueBackend::Auto,
                profiler: true,
            },
            sync: SyncConfig::default(),
            link: LinkConfig::default(),
            cluster: ClusterConfig::default(),
            lifecycle: LifecycleConfig::default(),
            fault: FaultConfig::default(),
            workers: 0,
            native_aggregation: false,
            artifacts_dir: "artifacts".into(),
        }
    }

    /// Paper-shaped CIFAR preset.
    pub fn cifar() -> Self {
        let mut c = Self::mnist();
        c.hfl.dataset = Dataset::Cifar;
        c.hfl.threshold_time = 12000.0;
        c.agent.epsilon = 0.03;
        c.agent.episodes = 8;
        c.sim.sgd_base_time = 8.0; // ~4x MNIST per-batch cost on a Pi
        c
    }

    pub fn preset(name: &str) -> Result<Self> {
        match name {
            "mnist" => Ok(Self::mnist()),
            "cifar" => Ok(Self::cifar()),
            _ => bail!("unknown preset '{name}'"),
        }
    }

    pub fn devices_per_edge(&self) -> usize {
        self.topology.devices / self.topology.edges
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref()).with_context(|| {
            format!("reading config {}", path.as_ref().display())
        })?;
        let j = Json::parse(&text)?;
        let preset = j
            .get("preset")
            .and_then(|p| p.as_str())
            .unwrap_or("mnist");
        let mut cfg = Self::preset(preset)?;
        if let Some(overrides) = j.get("overrides").and_then(|o| o.as_obj()) {
            for (k, v) in overrides {
                cfg.apply_override(k, &json_to_string(v))?;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply a dotted `key=value` override (CLI `--set`).
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<()> {
        let parse_f = || -> Result<f64> {
            value
                .parse::<f64>()
                .with_context(|| format!("value for {key} must be numeric"))
        };
        let parse_u = || -> Result<usize> {
            value
                .parse::<usize>()
                .with_context(|| format!("value for {key} must be an integer"))
        };
        match key {
            "seed" => self.seed = value.parse()?,
            "workers" => self.workers = parse_u()?,
            "native_aggregation" => {
                self.native_aggregation = value.parse().map_err(|_| {
                    anyhow::anyhow!("native_aggregation must be true|false")
                })?
            }
            "artifacts_dir" => self.artifacts_dir = value.to_string(),
            "topology.devices" => self.topology.devices = parse_u()?,
            "topology.edges" => self.topology.edges = parse_u()?,
            "topology.cn_fraction" => self.topology.cn_fraction = parse_f()?,
            "topology.nmax" => self.topology.nmax = parse_u()?,
            "hfl.dataset" => self.hfl.dataset = Dataset::parse(value)?,
            "hfl.partition" => {
                self.hfl.partition = parse_partition(value)?;
            }
            "hfl.samples_per_device" => {
                self.hfl.samples_per_device = parse_u()?
            }
            "hfl.threshold_time" => self.hfl.threshold_time = parse_f()?,
            "hfl.gamma1" => self.hfl.gamma1 = parse_u()?,
            "hfl.gamma2" => self.hfl.gamma2 = parse_u()?,
            "hfl.gamma1_max" => self.hfl.gamma1_max = parse_u()?,
            "hfl.gamma2_max" => self.hfl.gamma2_max = parse_u()?,
            "agent.episodes" => self.agent.episodes = parse_u()?,
            "agent.upsilon" => self.agent.upsilon = parse_f()?,
            "agent.epsilon" => self.agent.epsilon = parse_f()?,
            "agent.xi" => self.agent.xi = parse_f()?,
            "agent.lambda" => self.agent.lambda = parse_f()?,
            "agent.update_epochs" => self.agent.update_epochs = parse_u()?,
            "agent.traj_max" => self.agent.traj_max = parse_u()?,
            "agent.npca" => self.agent.npca = parse_u()?,
            "sim.sgd_base_time" => self.sim.sgd_base_time = parse_f()?,
            "sim.cpu_kappa" => self.sim.cpu_kappa = parse_f()?,
            "sim.time_jitter" => self.sim.time_jitter = parse_f()?,
            "sim.power_idle" => self.sim.power_idle = parse_f()?,
            "sim.power_max" => self.sim.power_max = parse_f()?,
            "sim.leave_prob" => self.sim.leave_prob = parse_f()?,
            "sim.join_prob" => self.sim.join_prob = parse_f()?,
            "sim.workers" => self.sim.workers = parse_u()?,
            "sim.queue_backend" => {
                self.sim.queue_backend =
                    crate::sim::event::QueueBackend::parse(value)?
            }
            "sim.profiler" => {
                self.sim.profiler = value.parse().map_err(|_| {
                    anyhow::anyhow!("sim.profiler must be true|false")
                })?
            }
            "sync.mode" => self.sync.mode = SyncModeCfg::parse(value)?,
            "sync.quorum" => self.sync.quorum = parse_u()?,
            "sync.staleness_alpha" => {
                self.sync.staleness_alpha = parse_f()?
            }
            "sync.cloud_interval" => self.sync.cloud_interval = parse_f()?,
            "sync.learned" => {
                self.sync.learned = value.parse().map_err(|_| {
                    anyhow::anyhow!("sync.learned must be true|false")
                })?
            }
            "sync.alpha_min" => self.sync.alpha_min = parse_f()?,
            "sync.alpha_max" => self.sync.alpha_max = parse_f()?,
            "link.up_bandwidth_scale" => {
                self.link.up_bandwidth_scale = parse_f()?
            }
            "link.down_bandwidth_scale" => {
                self.link.down_bandwidth_scale = parse_f()?
            }
            "cluster.recluster_threshold" => {
                self.cluster.recluster_threshold = parse_f()?
            }
            "cluster.recluster_min_interval" => {
                self.cluster.recluster_min_interval = parse_f()?
            }
            "link.contention" => {
                self.link.contention = value.parse().map_err(|_| {
                    anyhow::anyhow!("link.contention must be true|false")
                })?
            }
            "lifecycle.overselect" => self.lifecycle.overselect = parse_f()?,
            "lifecycle.pace_day" => self.lifecycle.pace_day = parse_f()?,
            "lifecycle.avail_frac" => self.lifecycle.avail_frac = parse_f()?,
            "fault.outages" => self.fault.outages = parse_u()?,
            "fault.outage_duration" => {
                self.fault.outage_duration = parse_f()?
            }
            "fault.partitions" => self.fault.partitions = parse_u()?,
            "fault.partition_duration" => {
                self.fault.partition_duration = parse_f()?
            }
            "fault.crash_storms" => self.fault.crash_storms = parse_u()?,
            "fault.crash_frac" => self.fault.crash_frac = parse_f()?,
            "fault.rejoin_delay" => self.fault.rejoin_delay = parse_f()?,
            _ => bail!("unknown config key '{key}'"),
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        let t = &self.topology;
        if t.devices == 0 || t.edges == 0 {
            bail!("devices and edges must be positive");
        }
        if t.devices % t.edges != 0 {
            bail!(
                "devices ({}) must be divisible by edges ({})",
                t.devices,
                t.edges
            );
        }
        if t.devices / t.edges > t.nmax {
            bail!(
                "devices per edge ({}) exceeds artifact Nmax ({})",
                t.devices / t.edges,
                t.nmax
            );
        }
        if t.edges > t.nmax {
            bail!("edges ({}) exceed artifact Nmax ({})", t.edges, t.nmax);
        }
        if !(0.0..=1.0).contains(&t.cn_fraction) {
            bail!("cn_fraction must be in [0,1]");
        }
        if self.hfl.gamma1 == 0 || self.hfl.gamma2 == 0 {
            bail!("gamma1/gamma2 must be >= 1");
        }
        if self.hfl.gamma1_max < self.hfl.gamma1
            || self.hfl.gamma2_max < self.hfl.gamma2
        {
            bail!("gamma maxima must dominate the defaults");
        }
        if self.hfl.threshold_time <= 0.0 {
            bail!("threshold_time must be positive");
        }
        if !(0.0 < self.agent.xi && self.agent.xi <= 1.0) {
            bail!("xi must be in (0,1]");
        }
        if !(0.0 < self.agent.lambda && self.agent.lambda <= 1.0) {
            bail!("lambda must be in (0,1]");
        }
        if !(0.0..=1.0).contains(&self.sim.leave_prob)
            || !(0.0..=1.0).contains(&self.sim.join_prob)
        {
            bail!("sim.leave_prob/join_prob must be probabilities in [0,1]");
        }
        if self.sync.staleness_alpha < 0.0 {
            bail!("sync.staleness_alpha must be >= 0");
        }
        if self.sync.cloud_interval <= 0.0 {
            bail!("sync.cloud_interval must be positive");
        }
        if !(self.sync.alpha_min.is_finite()
            && self.sync.alpha_max.is_finite()
            && self.sync.alpha_min >= 0.0
            && self.sync.alpha_max >= self.sync.alpha_min)
        {
            bail!(
                "sync.alpha_min/alpha_max must be finite with \
                 0 <= alpha_min <= alpha_max"
            );
        }
        if self.sync.learned && self.sync.mode == SyncModeCfg::Synchronous {
            bail!(
                "sync.learned drives the event engine; pick sync.mode \
                 semi-sync or async (the synchronous agent is the `arena` \
                 scheme, and `--scheme arena-async` sets both knobs \
                 automatically)"
            );
        }
        for (name, s) in [
            ("link.up_bandwidth_scale", self.link.up_bandwidth_scale),
            ("link.down_bandwidth_scale", self.link.down_bandwidth_scale),
        ] {
            if !(s.is_finite() && s > 0.0) {
                bail!("{name} must be a positive finite number (got {s})");
            }
        }
        if !self.cluster.recluster_threshold.is_finite() {
            bail!("cluster.recluster_threshold must be finite");
        }
        if !(self.cluster.recluster_min_interval.is_finite()
            && self.cluster.recluster_min_interval >= 0.0)
        {
            bail!("cluster.recluster_min_interval must be >= 0 and finite");
        }
        let lc = &self.lifecycle;
        if !lc.overselect.is_finite()
            || (lc.overselect != 0.0 && lc.overselect < 1.0)
        {
            bail!(
                "lifecycle.overselect must be 0 (off) or >= 1 \
                 (got {})",
                lc.overselect
            );
        }
        if !(lc.pace_day.is_finite() && lc.pace_day >= 0.0) {
            bail!("lifecycle.pace_day must be >= 0 and finite");
        }
        if !(0.0 < lc.avail_frac && lc.avail_frac <= 1.0) {
            bail!("lifecycle.avail_frac must be in (0,1]");
        }
        let fc = &self.fault;
        for (name, v) in [
            ("fault.outage_duration", fc.outage_duration),
            ("fault.partition_duration", fc.partition_duration),
            ("fault.rejoin_delay", fc.rejoin_delay),
        ] {
            if !(v.is_finite() && v > 0.0) {
                bail!("{name} must be a positive finite number (got {v})");
            }
        }
        if !(0.0..=1.0).contains(&fc.crash_frac) {
            bail!("fault.crash_frac must be in [0,1]");
        }
        Ok(())
    }

    /// Serialize for run provenance in results/ — complete enough that
    /// two configs with equal JSON produce the same run (the agent cache
    /// digests this to detect any environment/normalization change).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("dataset", Json::str(self.hfl.dataset.name())),
            ("partition", Json::str(self.hfl.partition.describe())),
            ("devices", Json::num(self.topology.devices as f64)),
            ("edges", Json::num(self.topology.edges as f64)),
            ("cn_fraction", Json::num(self.topology.cn_fraction)),
            ("nmax", Json::num(self.topology.nmax as f64)),
            (
                "samples_per_device",
                Json::num(self.hfl.samples_per_device as f64),
            ),
            ("threshold_time", Json::num(self.hfl.threshold_time)),
            ("gamma1", Json::num(self.hfl.gamma1 as f64)),
            ("gamma2", Json::num(self.hfl.gamma2 as f64)),
            ("gamma1_max", Json::num(self.hfl.gamma1_max as f64)),
            ("gamma2_max", Json::num(self.hfl.gamma2_max as f64)),
            ("episodes", Json::num(self.agent.episodes as f64)),
            ("upsilon", Json::num(self.agent.upsilon)),
            ("epsilon", Json::num(self.agent.epsilon)),
            ("xi", Json::num(self.agent.xi)),
            ("lambda", Json::num(self.agent.lambda)),
            (
                "update_epochs",
                Json::num(self.agent.update_epochs as f64),
            ),
            ("npca", Json::num(self.agent.npca as f64)),
            ("sync_mode", Json::str(self.sync.mode.name())),
            ("sync_quorum", Json::num(self.sync.quorum as f64)),
            (
                "sync_staleness_alpha",
                Json::num(self.sync.staleness_alpha),
            ),
            ("sync_cloud_interval", Json::num(self.sync.cloud_interval)),
            ("sync_learned", Json::Bool(self.sync.learned)),
            ("sync_alpha_min", Json::num(self.sync.alpha_min)),
            ("sync_alpha_max", Json::num(self.sync.alpha_max)),
            ("sgd_base_time", Json::num(self.sim.sgd_base_time)),
            ("cpu_kappa", Json::num(self.sim.cpu_kappa)),
            ("time_jitter", Json::num(self.sim.time_jitter)),
            ("power_idle", Json::num(self.sim.power_idle)),
            ("power_max", Json::num(self.sim.power_max)),
            ("cn_latency", Json::num(self.sim.cn_latency)),
            ("cn_bandwidth", Json::num(self.sim.cn_bandwidth)),
            ("us_latency", Json::num(self.sim.us_latency)),
            ("us_bandwidth", Json::num(self.sim.us_bandwidth)),
            ("comm_jitter", Json::num(self.sim.comm_jitter)),
            ("leave_prob", Json::num(self.sim.leave_prob)),
            ("join_prob", Json::num(self.sim.join_prob)),
            ("native_aggregation", Json::Bool(self.native_aggregation)),
            ("artifacts_dir", Json::str(&self.artifacts_dir)),
            (
                "recluster_threshold",
                Json::num(self.cluster.recluster_threshold),
            ),
            (
                "recluster_min_interval",
                Json::num(self.cluster.recluster_min_interval),
            ),
            ("link_up_scale", Json::num(self.link.up_bandwidth_scale)),
            ("link_down_scale", Json::num(self.link.down_bandwidth_scale)),
            ("link_contention", Json::Bool(self.link.contention)),
            // Lifecycle + fault knobs are trajectory-affecting (unlike
            // sim.workers/queue_backend/profiler, which stay excluded).
            (
                "lifecycle_overselect",
                Json::num(self.lifecycle.overselect),
            ),
            ("lifecycle_pace_day", Json::num(self.lifecycle.pace_day)),
            (
                "lifecycle_avail_frac",
                Json::num(self.lifecycle.avail_frac),
            ),
            ("fault_outages", Json::num(self.fault.outages as f64)),
            (
                "fault_outage_duration",
                Json::num(self.fault.outage_duration),
            ),
            ("fault_partitions", Json::num(self.fault.partitions as f64)),
            (
                "fault_partition_duration",
                Json::num(self.fault.partition_duration),
            ),
            (
                "fault_crash_storms",
                Json::num(self.fault.crash_storms as f64),
            ),
            ("fault_crash_frac", Json::num(self.fault.crash_frac)),
            ("fault_rejoin_delay", Json::num(self.fault.rejoin_delay)),
        ])
    }
}

fn parse_partition(value: &str) -> Result<Partition> {
    if value == "iid" {
        return Ok(Partition::Iid);
    }
    if let Some(rest) = value.strip_prefix("label") {
        return Ok(Partition::LabelSkew {
            labels: rest.parse().context("label<k>")?,
        });
    }
    if let Some(rest) = value.strip_prefix("dirichlet") {
        return Ok(Partition::Dirichlet {
            alpha: rest.parse().context("dirichlet<alpha>")?,
        });
    }
    bail!("unknown partition '{value}' (iid|label<k>|dirichlet<alpha>)")
}

fn json_to_string(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        ExperimentConfig::mnist().validate().unwrap();
        ExperimentConfig::cifar().validate().unwrap();
    }

    #[test]
    fn override_roundtrip() {
        let mut c = ExperimentConfig::mnist();
        c.apply_override("topology.devices", "20").unwrap();
        c.apply_override("topology.edges", "4").unwrap();
        c.apply_override("hfl.partition", "dirichlet0.5").unwrap();
        c.apply_override("agent.epsilon", "0.03").unwrap();
        assert_eq!(c.topology.devices, 20);
        assert!(matches!(
            c.hfl.partition,
            Partition::Dirichlet { alpha } if (alpha - 0.5).abs() < 1e-12
        ));
        c.validate().unwrap();
    }

    #[test]
    fn validation_catches_bad_topology() {
        let mut c = ExperimentConfig::mnist();
        c.topology.devices = 7; // not divisible by 5 edges
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::mnist();
        c.topology.devices = 100;
        c.topology.edges = 5; // 20 per edge > nmax 16
        assert!(c.validate().is_err());
    }

    #[test]
    fn sync_and_mobility_overrides() {
        let mut c = ExperimentConfig::mnist();
        c.apply_override("sync.mode", "semi-sync").unwrap();
        c.apply_override("sync.quorum", "3").unwrap();
        c.apply_override("sync.staleness_alpha", "0.7").unwrap();
        c.apply_override("sync.cloud_interval", "90").unwrap();
        c.apply_override("sim.leave_prob", "0.1").unwrap();
        c.apply_override("sim.join_prob", "0.4").unwrap();
        assert_eq!(c.sync.mode, SyncModeCfg::SemiSync);
        assert_eq!(c.sync.quorum, 3);
        assert!((c.sync.staleness_alpha - 0.7).abs() < 1e-12);
        assert!((c.sim.leave_prob - 0.1).abs() < 1e-12);
        c.validate().unwrap();
        c.apply_override("sync.mode", "async").unwrap();
        assert_eq!(c.sync.mode, SyncModeCfg::Async);
        assert!(c.apply_override("sync.mode", "bogus").is_err());
    }

    #[test]
    fn learned_sync_overrides_and_validation() {
        let mut c = ExperimentConfig::mnist();
        assert!(!c.sync.learned, "learned control defaults off");
        // Learned control requires an event-driven mode.
        c.apply_override("sync.learned", "true").unwrap();
        assert!(c.validate().is_err());
        c.apply_override("sync.mode", "async").unwrap();
        c.apply_override("sync.alpha_min", "0.1").unwrap();
        c.apply_override("sync.alpha_max", "1.5").unwrap();
        c.validate().unwrap();
        assert!(c.sync.learned);
        assert!((c.sync.alpha_min - 0.1).abs() < 1e-12);
        assert!((c.sync.alpha_max - 1.5).abs() < 1e-12);
        // Inverted or non-finite α bounds are rejected.
        c.sync.alpha_min = 2.0;
        assert!(c.validate().is_err());
        c.sync.alpha_min = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::mnist();
        assert!(c.apply_override("sync.learned", "maybe").is_err());
    }

    #[test]
    fn validation_catches_bad_sync_and_mobility() {
        let mut c = ExperimentConfig::mnist();
        c.sim.leave_prob = 1.5;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::mnist();
        c.sync.cloud_interval = 0.0;
        assert!(c.validate().is_err());
        let mut c = ExperimentConfig::mnist();
        c.sync.staleness_alpha = -0.1;
        assert!(c.validate().is_err());
    }

    #[test]
    fn link_overrides_and_validation() {
        let mut c = ExperimentConfig::mnist();
        assert!(c.link.contention, "contention defaults on");
        c.apply_override("link.up_bandwidth_scale", "0.25").unwrap();
        c.apply_override("link.down_bandwidth_scale", "4").unwrap();
        c.apply_override("link.contention", "false").unwrap();
        assert!((c.link.up_bandwidth_scale - 0.25).abs() < 1e-12);
        assert!((c.link.down_bandwidth_scale - 4.0).abs() < 1e-12);
        assert!(!c.link.contention);
        c.validate().unwrap();
        assert!(c.apply_override("link.contention", "maybe").is_err());
        c.link.up_bandwidth_scale = 0.0;
        assert!(c.validate().is_err());
        c.link.up_bandwidth_scale = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn cluster_overrides_and_validation() {
        let mut c = ExperimentConfig::mnist();
        assert_eq!(
            c.cluster.recluster_threshold, 0.0,
            "re-clustering defaults off"
        );
        c.apply_override("cluster.recluster_threshold", "0.15").unwrap();
        c.apply_override("cluster.recluster_min_interval", "120").unwrap();
        assert!((c.cluster.recluster_threshold - 0.15).abs() < 1e-12);
        assert!((c.cluster.recluster_min_interval - 120.0).abs() < 1e-12);
        c.validate().unwrap();
        c.cluster.recluster_min_interval = -1.0;
        assert!(c.validate().is_err());
        c.cluster.recluster_min_interval = 120.0;
        c.cluster.recluster_threshold = f64::NAN;
        assert!(c.validate().is_err());
    }

    #[test]
    fn lifecycle_and_fault_overrides_and_validation() {
        let mut c = ExperimentConfig::mnist();
        assert_eq!(c.lifecycle.overselect, 0.0, "over-selection defaults off");
        assert_eq!(c.lifecycle.pace_day, 0.0, "pace steering defaults off");
        assert_eq!(c.fault.outages, 0, "fault injection defaults off");
        c.apply_override("lifecycle.overselect", "1.3").unwrap();
        c.apply_override("lifecycle.pace_day", "3600").unwrap();
        c.apply_override("lifecycle.avail_frac", "0.6").unwrap();
        c.apply_override("fault.outages", "2").unwrap();
        c.apply_override("fault.outage_duration", "90").unwrap();
        c.apply_override("fault.partitions", "1").unwrap();
        c.apply_override("fault.partition_duration", "150").unwrap();
        c.apply_override("fault.crash_storms", "1").unwrap();
        c.apply_override("fault.crash_frac", "0.25").unwrap();
        c.apply_override("fault.rejoin_delay", "45").unwrap();
        assert!((c.lifecycle.overselect - 1.3).abs() < 1e-12);
        assert_eq!(c.fault.outages, 2);
        assert_eq!(c.fault.crash_storms, 1);
        c.validate().unwrap();
        // Over-selection factors between 0 and 1 would under-dispatch.
        c.lifecycle.overselect = 0.5;
        assert!(c.validate().is_err());
        c.lifecycle.overselect = 1.3;
        c.lifecycle.avail_frac = 0.0;
        assert!(c.validate().is_err());
        c.lifecycle.avail_frac = 0.6;
        c.fault.crash_frac = 1.5;
        assert!(c.validate().is_err());
        c.fault.crash_frac = 0.25;
        c.fault.rejoin_delay = 0.0;
        assert!(c.validate().is_err());
        c.fault.rejoin_delay = 45.0;
        c.validate().unwrap();
        // The new knobs are trajectory-affecting: they must show up in
        // the run-identity digest.
        let j = c.to_json().to_string();
        assert!(j.contains("lifecycle_overselect"));
        assert!(j.contains("fault_crash_storms"));
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = ExperimentConfig::mnist();
        assert!(c.apply_override("no.such.key", "1").is_err());
    }

    #[test]
    fn parallelism_overrides() {
        use crate::sim::event::QueueBackend;
        let mut c = ExperimentConfig::mnist();
        assert_eq!(c.sim.workers, 1, "serial by default");
        assert_eq!(c.sim.queue_backend, QueueBackend::Auto);
        c.apply_override("sim.workers", "8").unwrap();
        c.apply_override("sim.queue_backend", "calendar").unwrap();
        assert_eq!(c.sim.workers, 8);
        assert_eq!(c.sim.queue_backend, QueueBackend::Calendar);
        c.apply_override("sim.queue_backend", "heap").unwrap();
        assert_eq!(c.sim.queue_backend, QueueBackend::Binary);
        assert!(c.sim.profiler, "profiler defaults on");
        c.apply_override("sim.profiler", "false").unwrap();
        assert!(!c.sim.profiler);
        c.validate().unwrap();
        assert!(c.apply_override("sim.queue_backend", "bogus").is_err());
        assert!(c.apply_override("sim.workers", "-1").is_err());
        assert!(c.apply_override("sim.profiler", "maybe").is_err());
        // Execution details must stay out of the run-identity digest.
        let base = ExperimentConfig::mnist().to_json().to_string();
        assert_eq!(c.to_json().to_string(), base);
    }

    #[test]
    fn load_from_json_file() {
        let dir = std::env::temp_dir().join("arena_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.json");
        std::fs::write(
            &path,
            r#"{"preset": "cifar",
               "overrides": {"hfl.gamma1": 3, "seed": 7}}"#,
        )
        .unwrap();
        let c = ExperimentConfig::load(&path).unwrap();
        assert_eq!(c.hfl.dataset, Dataset::Cifar);
        assert_eq!(c.hfl.gamma1, 3);
        assert_eq!(c.seed, 7);
        std::fs::remove_dir_all(dir).ok();
    }
}
