//! Bench: one full HFL cloud round end-to-end (train + aggregate + eval),
//! the Fig. 8/9 inner loop. `cargo bench --bench hfl_round`

use arena::config::ExperimentConfig;
use arena::hfl::HflEngine;
use arena::util::microbench::bench;

fn main() {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    std::env::set_var("ARENA_BENCH_FAST", "1"); // rounds are seconds-scale
    let dir = std::env::var("ARENA_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let mut cfg = ExperimentConfig::mnist();
    cfg.topology.devices = 10;
    cfg.hfl.threshold_time = 1e9; // never stop inside the bench
    cfg.artifacts_dir = dir;
    let mut engine = HflEngine::new(cfg, true).expect("engine");
    let m = engine.edges();
    for (g1, g2) in [(1usize, 1usize), (5, 1), (5, 4)] {
        let g1v = vec![g1; m];
        let g2v = vec![g2; m];
        bench(&format!("hfl_round/g1={g1}/g2={g2}"), || {
            engine.run_round(&g1v, &g2v, None).unwrap();
        });
    }
}
