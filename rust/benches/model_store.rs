//! Bench: the copy-on-write versioned model store under the engines'
//! model-movement patterns at 10k–1M devices — the proof that breaking
//! the O(N·p) device-model wall holds at scale:
//!
//! * `broadcast/{n}` — re-point every device handle to a fresh cloud
//!   buffer (what used to memcpy p floats per device). Per-device cost
//!   must stay flat from 10k to 1M devices (`broadcast_per_device/{n}`
//!   records it explicitly so the guard pins it).
//! * `checkout_release/{n}` — a 1k-device training burst: CoW checkout
//!   (materialize a private pooled buffer) + release back to sharing.
//!   Cost depends on the burst, not on the population size.
//! * `migrate/{n}` — a 10% recluster migration wave: warm-starts are
//!   handle re-points to the destination edges' models.
//!
//! No artifacts needed. `cargo bench --bench model_store` — also
//! rewrites `BENCH_model_store.json` at the repo root with the measured
//! numbers (guarded >2x by `.github/scripts/bench_guard.py` in CI once
//! a recorded baseline is committed).

use std::collections::BTreeMap;

use arena::hfl::model_store::{ModelRef, ModelStore, ShardedModelStore};
use arena::util::json::Json;
use arena::util::microbench::{bench, black_box, BenchResult};
use arena::util::threadpool::par_for_each;

/// Small on purpose: handle traffic is O(1) in p by construction; a big
/// p would only turn the CoW workload into a memcpy bench.
const P: usize = 1024;

fn main() {
    let mut results = Vec::new();
    for &n in &[10_000usize, 100_000, 1_000_000] {
        // ---- broadcast: n handle re-points, zero copies ----------------
        let mut store = ModelStore::new(P);
        let cloud_a = store.insert(vec![0.0; P], 1);
        let cloud_b = store.insert(vec![1.0; P], 2);
        let mut devices: Vec<ModelRef> =
            (0..n).map(|_| store.share(&cloud_a)).collect();
        let mut flip = false;
        let r = bench(&format!("model_store/broadcast/{n}"), || {
            let src = if flip { &cloud_a } else { &cloud_b };
            for d in devices.iter_mut() {
                store.repoint(d, src);
            }
            flip = !flip;
            black_box(store.live_buffers());
        });
        // The acceptance metric: flat per-device cost across n.
        results.push(BenchResult {
            name: format!("model_store/broadcast_per_device/{n}"),
            iters: r.iters,
            mean_ns: r.mean_ns / n as f64,
            p50_ns: r.p50_ns / n as f64,
            p99_ns: r.p99_ns / n as f64,
        });
        results.push(r);
        for d in devices.drain(..) {
            store.release(d);
        }
        store.release(cloud_a);
        store.release(cloud_b);
        store.assert_consistent();

        // ---- checkout/release: CoW training burst + pool reuse ---------
        let mut store = ModelStore::new(P);
        let cloud = store.insert(vec![0.0; P], 1);
        let mut devices: Vec<ModelRef> =
            (0..n).map(|_| store.share(&cloud)).collect();
        let burst = 1_000usize;
        results.push(bench(
            &format!("model_store/checkout_release/{n}"),
            || {
                for i in 0..burst {
                    let d = (i * 997) % n;
                    store.make_mut(&mut devices[d])[0] += 1.0;
                }
                for i in 0..burst {
                    let d = (i * 997) % n;
                    store.repoint(&mut devices[d], &cloud);
                }
                black_box(store.live_buffers());
            },
        ));
        assert!(
            store.allocated_buffers() <= burst + 2,
            "pool failed to bound the working set: {} buffers",
            store.allocated_buffers()
        );
        for d in devices.drain(..) {
            store.release(d);
        }
        store.release(cloud);
        store.assert_consistent();

        // ---- recluster migration: 10% warm-start wave ------------------
        let m = 64usize;
        let mut store = ModelStore::new(P);
        let edges: Vec<ModelRef> =
            (0..m).map(|j| store.insert(vec![j as f32; P], 1)).collect();
        let mut devices: Vec<ModelRef> =
            (0..n).map(|d| store.share(&edges[d % m])).collect();
        let mut round = 0usize;
        results.push(bench(&format!("model_store/migrate/{n}"), || {
            round += 1;
            for d in (0..n).step_by(10) {
                let dst = (d / 10 + round) % m;
                store.repoint(&mut devices[d], &edges[dst]);
            }
            black_box(store.live_buffers());
        }));
        for d in devices.drain(..) {
            store.release(d);
        }
        for e in edges {
            store.release(e);
        }
        store.assert_consistent();
    }

    // ---- sharded store: per-shard slabs under a worker sweep -----------
    // 1M+ device handles (65k under ARENA_BENCH_FAST) split over 64
    // shard slabs; each worker broadcasts its shards' handles — the
    // slabs are disjoint, so there is no synchronization on the hot
    // path. `workers/{w}` records per-repoint ns; `threads_speedup/{w}`
    // stores the run(1)/run(w) wall ratio (dimensionless) in mean_ns.
    {
        let fast = std::env::var("ARENA_BENCH_FAST").is_ok();
        let n = if fast { 1 << 16 } else { 1_048_576 };
        let s_n = 64usize;
        let per = n / s_n;
        let mut st = ShardedModelStore::new(P, s_n);
        // Per shard: an (a, b) cloud pair plus its device handles, all
        // living in that shard's slab.
        let mut ctx: Vec<(ModelRef, ModelRef, Vec<ModelRef>)> = st
            .shards_mut()
            .iter_mut()
            .map(|ms| {
                let a = ms.insert(vec![0.0; P], 1);
                let b = ms.insert(vec![1.0; P], 2);
                let devs = (0..per).map(|_| ms.share(&a)).collect();
                (a, b, devs)
            })
            .collect();
        let mut base_ns = 1.0f64;
        for &w in &[1usize, 2, 4, 8] {
            let t0 = std::time::Instant::now();
            let items: Vec<_> =
                st.shards_mut().iter_mut().zip(ctx.iter_mut()).collect();
            // There-and-back: state is identical before and after, so
            // every worker count measures the same workload.
            par_for_each(w, items, |(ms, (a, b, devs))| {
                for d in devs.iter_mut() {
                    ms.repoint(d, b);
                }
                for d in devs.iter_mut() {
                    ms.repoint(d, a);
                }
            });
            let ns = (t0.elapsed().as_nanos() as f64).max(1.0);
            if w == 1 {
                base_ns = ns;
            }
            let repoints = (2 * s_n * per) as f64;
            let r = BenchResult {
                name: format!("model_store/sharded_broadcast/workers/{w}"),
                iters: repoints as u64,
                mean_ns: ns / repoints,
                p50_ns: ns / repoints,
                p99_ns: ns / repoints,
            };
            r.report();
            results.push(r);
            let sp = BenchResult {
                name: format!(
                    "model_store/sharded_broadcast/threads_speedup/{w}"
                ),
                iters: 1,
                mean_ns: base_ns / ns,
                p50_ns: base_ns / ns,
                p99_ns: base_ns / ns,
            };
            sp.report();
            results.push(sp);
        }
        for (s, (a, b, devs)) in ctx.into_iter().enumerate() {
            let ms = &mut st.shards_mut()[s];
            for d in devs {
                ms.release(d);
            }
            ms.release(a);
            ms.release(b);
        }
        st.assert_consistent();
    }

    // Flatness summary for the log (the recorded JSON is the artifact).
    println!("\nper-device broadcast cost (must stay flat in n):");
    for r in &results {
        if r.name.starts_with("model_store/broadcast_per_device/") {
            println!("  {:<42} {:>8.2} ns/device", r.name, r.mean_ns);
        }
    }

    if let Err(e) = write_json(&results) {
        eprintln!("warning: could not write BENCH_model_store.json: {e}");
    }
}

/// Record the run at the repo root (benches run with CWD = rust/).
fn write_json(results: &[BenchResult]) -> std::io::Result<()> {
    let mut root = BTreeMap::new();
    root.insert(
        "generated_by".to_string(),
        Json::Str("cargo bench --bench model_store".into()),
    );
    root.insert(
        "note".to_string(),
        Json::Str(
            "per-iteration ns; broadcast_per_device is per-device ns and \
             must stay flat from 10k to 1M devices (O(1) handle re-point \
             — the model-store acceptance metric); \
             sharded_broadcast/workers/W is per-repoint ns over 64 \
             disjoint shard slabs on W threads and threads_speedup/W \
             stores the run(1)/run(W) wall ratio — dimensionless — in \
             mean_ns"
                .into(),
        ),
    );
    let mut arr = Vec::new();
    for r in results {
        let mut e = BTreeMap::new();
        e.insert("name".to_string(), Json::Str(r.name.clone()));
        e.insert("iters".to_string(), Json::Num(r.iters as f64));
        e.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
        e.insert("p50_ns".to_string(), Json::Num(r.p50_ns));
        e.insert("p99_ns".to_string(), Json::Num(r.p99_ns));
        arr.push(Json::Obj(e));
    }
    root.insert("results".to_string(), Json::Arr(arr));
    let path = if std::path::Path::new("../BENCH_model_store.json").exists()
        || std::path::Path::new("../ROADMAP.md").exists()
    {
        "../BENCH_model_store.json"
    } else {
        "BENCH_model_store.json"
    };
    std::fs::write(path, Json::Obj(root).to_pretty())
}
