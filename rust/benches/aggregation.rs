//! Bench: the fedavg_reduce Pallas artifact vs a naive rust loop — the
//! HFL synchronization hot path (paper Eq. 1/2) — plus the serial vs
//! pooled-parallel A/B of the native reduction at large `p` (the
//! deterministic chunked kernel; results are bit-identical by
//! construction, asserted here too). The native A/B needs no artifacts.
//! `cargo bench --bench aggregation`

use arena::hfl::aggregate::{aggregate_native, aggregate_native_par};
use arena::runtime::{HostTensor, Runtime};
use arena::util::microbench::{bench, black_box};
use arena::util::rng::Rng;

/// Serial vs parallel native aggregation at model-store scale.
fn native_ab() {
    let mut rng = Rng::new(3);
    for &p in &[1usize << 18, 1 << 21] {
        let n_models = 8;
        let models: Vec<Vec<f32>> = (0..n_models)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> =
            models.iter().map(|m| m.as_slice()).collect();
        let weights: Vec<f32> =
            (0..n_models).map(|i| 1.0 + i as f32).collect();
        let serial = aggregate_native(&refs, &weights, p);
        bench(&format!("aggregate/native-serial/p{p}"), || {
            black_box(aggregate_native(&refs, &weights, p));
        });
        for &workers in &[2usize, 4, 8] {
            let par = aggregate_native_par(&refs, &weights, p, workers);
            assert_eq!(par, serial, "parallel kernel diverged bitwise");
            bench(&format!("aggregate/native-par{workers}/p{p}"), || {
                black_box(aggregate_native_par(&refs, &weights, p, workers));
            });
        }
    }
}

fn main() {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    native_ab();
    let dir = std::env::var("ARENA_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping artifact A/B: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(&dir, &["mnist_aggregate", "cifar_aggregate"])
        .expect("load artifacts");
    let mut rng = Rng::new(1);
    for ds in ["mnist", "cifar"] {
        let p = rt.manifest.param_count(ds).unwrap();
        let nmax = rt.manifest.config.nmax;
        let n_models = 10;
        let mut flat = vec![0.0f32; nmax * p];
        for v in flat.iter_mut().take(n_models * p) {
            *v = rng.normal() as f32;
        }
        let mut weights = vec![0.0f32; nmax];
        for w in weights.iter_mut().take(n_models) {
            *w = 1.0;
        }

        let art = format!("{ds}_aggregate");
        let models_t = HostTensor::f32(vec![nmax, p], flat.clone());
        let weights_t = HostTensor::f32(vec![nmax], weights.clone());
        bench(&format!("aggregate/{ds}/pallas-artifact"), || {
            let out = rt
                .execute(&art, &[models_t.clone(), weights_t.clone()])
                .unwrap();
            black_box(out);
        });

        bench(&format!("aggregate/{ds}/naive-rust"), || {
            let wsum: f32 = weights.iter().sum();
            let mut out = vec![0.0f32; p];
            for i in 0..nmax {
                let w = weights[i];
                if w == 0.0 {
                    continue;
                }
                let row = &flat[i * p..(i + 1) * p];
                for (o, &x) in out.iter_mut().zip(row) {
                    *o += w * x;
                }
            }
            for o in out.iter_mut() {
                *o /= wsum;
            }
            black_box(out);
        });
    }
}
