//! Bench: DRL state construction — PCA fit (Gram + Jacobi), artifact
//! projection, and the full state assembly (paper §3.2).
//! `cargo bench --bench state_build`

use arena::pca::PcaModel;
use arena::util::microbench::{bench, black_box};
use arena::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    for p in [21_840usize, 453_845] {
        let models: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = models.iter().map(|m| m.as_slice()).collect();
        bench(&format!("pca/fit/p={p}"), || {
            let pca = PcaModel::fit(&refs, 6);
            black_box(pca);
        });
        let pca = PcaModel::fit(&refs, 6);
        bench(&format!("pca/transform-cpu/p={p}"), || {
            let scores = pca.transform_cpu(&refs);
            black_box(scores);
        });
    }
}
