//! Bench: PJRT dispatch overhead — the smallest artifact (ppo_actor_fwd)
//! round trip, plus the literal conversion cost in isolation.
//! `cargo bench --bench exec_overhead`

use arena::runtime::{HostTensor, Runtime};
use arena::util::microbench::{bench, black_box};

fn main() {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let dir = std::env::var("ARENA_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = Runtime::load(&dir, &["ppo_actor_fwd"]).expect("load");
    let pp = rt.manifest.param_count("ppo").unwrap();
    let theta = rt.load_init_params("ppo").unwrap();
    let c = &rt.manifest.config;
    let state = vec![0.1f32; (c.m_edges + 1) * (c.npca + 3)];
    let theta_t = HostTensor::f32(vec![pp], theta);
    let state_t = HostTensor::f32(
        vec![c.m_edges + 1, c.npca + 3],
        state,
    );

    bench("exec/ppo_actor_fwd-roundtrip", || {
        let out = rt
            .execute("ppo_actor_fwd", &[theta_t.clone(), state_t.clone()])
            .unwrap();
        black_box(out);
    });

    bench("exec/literal-conversion-only", || {
        let lit = theta_t.to_literal().unwrap();
        black_box(lit);
    });
}
