//! Bench: the discrete-event scheduler under asynchronous-HFL load —
//! ≥10k device events scheduled and drained per iteration (the target is
//! that the queue never shows up in an async-run profile next to real
//! training). Coarse timestamps force heavy tie-break traffic, the worst
//! case for the seeded ordering. The transfer-heavy workloads push to 1M
//! events with interleaved `TransferDone`s (including the stale
//! re-prediction pattern of link contention) — the baseline for the
//! ROADMAP "event-queue scale-out" item. The churn-heavy workload mixes
//! `MobilityFlip`/`Recluster` events in, and `membership/plan_recluster`
//! prices one full re-clustering of a churned population. No artifacts
//! needed.
//!
//! `cargo bench --bench event_queue` — also rewrites
//! `BENCH_event_queue.json` at the repo root with the measured numbers.

use std::collections::BTreeMap;

use arena::hfl::membership::plan_recluster;
use arena::hfl::{EngineLoopSpec, ShardedEngineLoop};
use arena::obs::{Histogram, RunObserver};
use arena::sim::{
    Event, EventQueue, QueueBackend, Region, ShardSpec, ShardedDeviceSim,
};
use arena::util::json::Json;
use arena::util::microbench::{bench, black_box, BenchResult};
use arena::util::rng::Rng;

fn main() {
    let mut results = Vec::new();
    for &n in &[10_000usize, 100_000] {
        results.push(bench(
            &format!("event_queue/schedule+drain/{n}"),
            || {
                let mut q = EventQueue::new(42);
                for i in 0..n {
                    // ~500 distinct timestamps -> ~n/500 ties per slot.
                    let t = ((i * 7919) % 500) as f64 * 0.25;
                    q.schedule(
                        t,
                        Event::DeviceTrainDone {
                            device: i % 10_000,
                            edge: i % 8,
                        },
                    );
                }
                let mut last = -1.0f64;
                while let Some((t, ev)) = q.pop() {
                    debug_assert!(t >= last);
                    last = t;
                    black_box(ev);
                }
                black_box(last);
            },
        ));

        // Steady-state churn: the queue holds n events while each pop
        // reschedules one — the async engine's actual access pattern.
        results.push(bench(&format!("event_queue/steady_state/{n}"), || {
            let mut q = EventQueue::new(7);
            for i in 0..n {
                q.schedule(
                    (i % 500) as f64,
                    Event::DeviceTrainDone {
                        device: i,
                        edge: i % 8,
                    },
                );
            }
            for _ in 0..n {
                let (t, ev) = q.pop().unwrap();
                q.schedule(t + 500.0, ev);
            }
            black_box(q.len());
        }));
    }

    // Transfer-heavy: the queue under the transfer layer's event pattern —
    // TransferDone storms interleaved with training/aggregation events,
    // scaled to 1M events per drain.
    for &n in &[100_000usize, 1_000_000] {
        results.push(bench(
            &format!("event_queue/transfer_heavy/{n}"),
            || {
                let mut q = EventQueue::new(13);
                for i in 0..n {
                    let t = ((i * 31) % 2000) as f64 * 0.5;
                    let ev = match i % 3 {
                        0 => Event::TransferDone { transfer: i },
                        1 => Event::DeviceTrainDone {
                            device: i % 100_000,
                            edge: i % 16,
                        },
                        _ => Event::EdgeAggregate { edge: i % 16 },
                    };
                    q.schedule(t, ev);
                }
                while let Some((_, ev)) = q.pop() {
                    black_box(ev);
                }
            },
        ));

        // Contention re-prediction churn: every popped TransferDone
        // schedules a superseding prediction for a sibling transfer (the
        // link layer's stale-event pattern), so the queue sees ~2x the
        // logical transfer count.
        results.push(bench(
            &format!("event_queue/transfer_repredict/{n}"),
            || {
                let mut q = EventQueue::new(17);
                let seed_events = n / 2;
                for i in 0..seed_events {
                    q.schedule(
                        ((i * 53) % 1000) as f64,
                        Event::TransferDone { transfer: i },
                    );
                }
                let mut budget = n - seed_events;
                while let Some((t, ev)) = q.pop() {
                    if budget > 0 {
                        if let Event::TransferDone { transfer } = ev {
                            q.schedule(
                                t + 7.5,
                                Event::TransferDone {
                                    transfer: transfer ^ 1,
                                },
                            );
                            budget -= 1;
                        }
                    }
                    black_box(ev);
                }
            },
        ));
    }

    // Churn-heavy: the event mix of a mobile population — MobilityFlip
    // and Recluster events threaded through training/transfer storms
    // (the membership subsystem's queue-side footprint).
    for &n in &[100_000usize, 1_000_000] {
        results.push(bench(&format!("event_queue/churn_heavy/{n}"), || {
            let mut q = EventQueue::new(23);
            for i in 0..n {
                let t = ((i * 37) % 4000) as f64 * 0.25;
                let ev = match i % 16 {
                    0 => Event::MobilityFlip,
                    1 => Event::Recluster,
                    2..=6 => Event::TransferDone { transfer: i },
                    7 | 8 => Event::EdgeAggregate { edge: i % 16 },
                    _ => Event::DeviceTrainDone {
                        device: i % 50_000,
                        edge: i % 16,
                    },
                };
                q.schedule(t, ev);
            }
            while let Some((_, ev)) = q.pop() {
                black_box(ev);
            }
        }));
    }

    // Re-push hot path, per backend: `Event` is `Copy`, so re-pushing a
    // popped event allocates nothing (the old re-box showed up here).
    // Also the binary-vs-calendar head-to-head on an identical stream —
    // the two backends pop identical sequences by construction, so any
    // delta is pure data-structure cost.
    for backend in [QueueBackend::Binary, QueueBackend::Calendar] {
        let n = 100_000usize;
        results.push(bench(
            &format!("event_queue/push_pop/{}/{n}", backend.name()),
            || {
                let mut q = EventQueue::for_scale(31, n, backend);
                for i in 0..n {
                    q.schedule(
                        ((i * 37) % 4000) as f64 * 0.25,
                        Event::DeviceTrainDone {
                            device: i,
                            edge: i % 16,
                        },
                    );
                }
                for _ in 0..n {
                    let (t, ev) = q.pop().unwrap();
                    q.schedule(t + 1000.0, ev);
                }
                while let Some((_, ev)) = q.pop() {
                    black_box(ev);
                }
            },
        ));
    }

    // Sharded parallel engine at 1M+ devices (ARENA_BENCH_FAST shrinks
    // the population so CI stays a smoke): one timed run per worker
    // count, construction excluded. `workers/{w}` records per-event ns;
    // `threads_speedup/{w}` records run(1)/run(w) wall ratio as a
    // dimensionless number in the mean_ns field (see JSON note). The
    // merged trajectory is bitwise identical at every worker count —
    // the sweep only measures wall-clock.
    {
        let fast = std::env::var("ARENA_BENCH_FAST").is_ok();
        let devices = if fast { 1 << 16 } else { 1_048_576 };
        let mut base_ns = 1.0f64;
        for &w in &[1usize, 2, 4, 8] {
            let spec = ShardSpec {
                devices,
                edges: 64,
                windows: 2,
                workers: w,
                ..ShardSpec::default()
            };
            let mut sim = ShardedDeviceSim::new(&spec);
            let t0 = std::time::Instant::now();
            sim.run();
            let ns = (t0.elapsed().as_nanos() as f64).max(1.0);
            let events = sim.stats().events.max(1);
            if w == 1 {
                base_ns = ns;
            }
            let r = BenchResult {
                name: format!("event_queue/sharded_sim/workers/{w}"),
                iters: events,
                mean_ns: ns / events as f64,
                p50_ns: ns / events as f64,
                p99_ns: ns / events as f64,
            };
            r.report();
            results.push(r);
            let sp = BenchResult {
                name: format!(
                    "event_queue/sharded_sim/threads_speedup/{w}"
                ),
                iters: 1,
                mean_ns: base_ns / ns,
                p50_ns: base_ns / ns,
                p99_ns: base_ns / ns,
            };
            sp.report();
            results.push(sp);
        }
    }

    // The full engine-shard event loop (AsyncHflEngine's timer modes
    // minus the model math) at 1M+ devices: semi-sync quorums with
    // over-selection, churn flips and a seeded fault storm on the ctrl
    // timeline — the trajectory the multithread-determinism CI job
    // diffs. One timed run per worker count, construction excluded.
    // `engine_loop/workers/{w}` records per-event ns;
    // `engine_loop/threads_speedup/{w}` stores run(1)/run(w) wall ratio
    // (dimensionless) in mean_ns — the acceptance gate wants > 1.0 at
    // 8 workers. Byte-identical history CSVs are asserted across the
    // sweep here too.
    {
        let fast = std::env::var("ARENA_BENCH_FAST").is_ok();
        let devices = if fast { 1 << 16 } else { 1_048_576 };
        let mut base_ns = 1.0f64;
        let mut csv1: Option<String> = None;
        for &w in &[1usize, 2, 4, 8] {
            let spec = EngineLoopSpec {
                devices,
                edges: 64,
                windows: 2,
                workers: w,
                quorum: 3,
                overselect: 1.3,
                leave_prob: 0.05,
                join_prob: 0.05,
                ..EngineLoopSpec::default()
            };
            let mut sim = ShardedEngineLoop::new(&spec);
            let t0 = std::time::Instant::now();
            sim.run();
            let ns = (t0.elapsed().as_nanos() as f64).max(1.0);
            let events = sim.total_events().max(1);
            match &csv1 {
                None => csv1 = Some(sim.csv_string()),
                Some(base) => assert_eq!(
                    base,
                    &sim.csv_string(),
                    "engine loop must be bitwise identical (workers={w})"
                ),
            }
            if w == 1 {
                base_ns = ns;
            }
            let r = BenchResult {
                name: format!("event_queue/engine_loop/workers/{w}"),
                iters: events,
                mean_ns: ns / events as f64,
                p50_ns: ns / events as f64,
                p99_ns: ns / events as f64,
            };
            r.report();
            results.push(r);
            let sp = BenchResult {
                name: format!(
                    "event_queue/engine_loop/threads_speedup/{w}"
                ),
                iters: 1,
                mean_ns: base_ns / ns,
                p50_ns: base_ns / ns,
                p99_ns: base_ns / ns,
            };
            sp.report();
            results.push(sp);
        }
    }

    // Profiler overhead on the sharded engine: the same spec run bare
    // (profiler off, no observer) vs fully profiled (RunObserver
    // attached, per-shard profiler recording on the hot path and the
    // registry folding at every barrier). `profiler_overhead/{w}`
    // stores the profiled/bare wall ratio — dimensionless, target
    // <1.05 — in mean_ns; `barrier_stall_ns/{w}` reports the profiled
    // run's stall distribution (arrival spread at the window barrier)
    // and `shard_imbalance_x1000/{w}` the final max/mean events gauge.
    {
        let fast = std::env::var("ARENA_BENCH_FAST").is_ok();
        let devices = if fast { 1 << 16 } else { 1_048_576 };
        for &w in &[1usize, 8] {
            let spec = ShardSpec {
                devices,
                edges: 64,
                windows: 2,
                workers: w,
                ..ShardSpec::default()
            };
            let mut bare = ShardedDeviceSim::new(&spec);
            bare.set_profiler(false);
            let t0 = std::time::Instant::now();
            bare.run();
            let bare_ns = (t0.elapsed().as_nanos() as f64).max(1.0);
            let events = bare.stats().events.max(1);

            let obs = RunObserver::new();
            let state = obs.state();
            let mut prof = ShardedDeviceSim::new(&spec);
            prof.attach_observer(Box::new(obs));
            let t0 = std::time::Instant::now();
            prof.run();
            let prof_ns = (t0.elapsed().as_nanos() as f64).max(1.0);
            assert_eq!(
                bare.csv_string(),
                prof.csv_string(),
                "profiler must be bitwise invisible (workers={w})"
            );

            let r = BenchResult {
                name: format!("event_queue/sharded_sim/profiled/{w}"),
                iters: events,
                mean_ns: prof_ns / events as f64,
                p50_ns: prof_ns / events as f64,
                p99_ns: prof_ns / events as f64,
            };
            r.report();
            results.push(r);
            let ov = BenchResult {
                name: format!(
                    "event_queue/sharded_sim/profiler_overhead/{w}"
                ),
                iters: 1,
                mean_ns: prof_ns / bare_ns,
                p50_ns: prof_ns / bare_ns,
                p99_ns: prof_ns / bare_ns,
            };
            ov.report();
            results.push(ov);

            let st = state.lock().unwrap();
            if let Some(h) =
                st.registry.histogram("arena_shard_barrier_stall_ns")
            {
                let s = BenchResult {
                    name: format!(
                        "event_queue/sharded_sim/barrier_stall_ns/{w}"
                    ),
                    iters: h.count(),
                    mean_ns: h.mean(),
                    p50_ns: h.percentile(50.0),
                    p99_ns: h.percentile(99.0),
                };
                s.report();
                results.push(s);
            }
            let imb = st
                .registry
                .gauge("arena_shard_imbalance")
                .unwrap_or(1.0);
            let ib = BenchResult {
                name: format!(
                    "event_queue/sharded_sim/shard_imbalance_x1000/{w}"
                ),
                iters: 1,
                mean_ns: imb * 1000.0,
                p50_ns: imb * 1000.0,
                p99_ns: imb * 1000.0,
            };
            ib.report();
            results.push(ib);
        }
    }

    // Fault storm on the sharded engine at 1M+ devices: two edge
    // outages, a partition and a crash/rejoin storm layered on the
    // churny population — the injected-fault handlers (straggler voids,
    // severed uploads, mass rejoin re-dispatch) priced on the same
    // per-event scale as the clean runs. `fault_storm/{w}` records
    // per-event ns; the merged trajectory (faults column included) must
    // stay byte-identical across worker counts, asserted here.
    {
        let fast = std::env::var("ARENA_BENCH_FAST").is_ok();
        let devices = if fast { 1 << 16 } else { 1_048_576 };
        let mut csv1: Option<String> = None;
        for &w in &[1usize, 8] {
            let spec = ShardSpec {
                devices,
                edges: 64,
                windows: 3,
                workers: w,
                outages: 2,
                outage_duration: 70.0,
                partitions: 1,
                partition_duration: 100.0,
                crash_storms: 1,
                crash_frac: 0.4,
                rejoin_delay: 50.0,
                ..ShardSpec::default()
            };
            let mut sim = ShardedDeviceSim::new(&spec);
            let t0 = std::time::Instant::now();
            sim.run();
            let ns = (t0.elapsed().as_nanos() as f64).max(1.0);
            let events = sim.stats().events.max(1);
            match &csv1 {
                None => csv1 = Some(sim.csv_string()),
                Some(base) => assert_eq!(
                    base,
                    &sim.csv_string(),
                    "fault storm must be bitwise identical (workers={w})"
                ),
            }
            let r = BenchResult {
                name: format!("event_queue/fault_storm/{w}"),
                iters: events,
                mean_ns: ns / events as f64,
                p50_ns: ns / events as f64,
                p99_ns: ns / events as f64,
            };
            r.report();
            results.push(r);
        }
    }

    // Observer overhead on the drain hot path — the exact engine
    // pattern. `drain_bare` is the observer-detached loop (no clock
    // reads at all); `drain_observed` pays the full instrumentation
    // cost: two monotonic clock reads per event plus a log₂-histogram
    // record of the dequeue lag, i.e. what `RunObserver` folds into
    // its registry per event. The delta between the two JSON entries
    // is the measured cost of observation (<5% is the target); the
    // lag distribution itself is reported through the histogram — the
    // same p50/p99 `/metrics` exposes as arena_event_dequeue_lag_ns.
    {
        let n = 100_000usize;
        let fill = |q: &mut EventQueue| {
            for i in 0..n {
                let t = ((i * 37) % 4000) as f64 * 0.25;
                q.schedule(
                    t,
                    Event::DeviceTrainDone {
                        device: i % 50_000,
                        edge: i % 16,
                    },
                );
            }
        };
        results.push(bench(&format!("event_queue/drain_bare/{n}"), || {
            let mut q = EventQueue::new(29);
            fill(&mut q);
            while let Some((_, ev)) = q.pop() {
                black_box(ev);
            }
        }));

        let mut lag = Histogram::new();
        results.push(bench(
            &format!("event_queue/drain_observed/{n}"),
            || {
                let mut q = EventQueue::new(29);
                fill(&mut q);
                loop {
                    let t_pop = std::time::Instant::now();
                    let Some((_, ev)) = q.pop() else { break };
                    let t_handle = std::time::Instant::now();
                    black_box(&ev);
                    let lag_ns =
                        t_handle.duration_since(t_pop).as_nanos() as u64;
                    let handler_ns =
                        t_handle.elapsed().as_nanos() as u64;
                    lag.record(lag_ns as f64);
                    black_box(handler_ns);
                }
            },
        ));
        let lag_summary = BenchResult {
            name: format!("event_queue/dequeue_lag_ns/{n}"),
            iters: lag.count(),
            mean_ns: lag.mean(),
            p50_ns: lag.percentile(50.0),
            p99_ns: lag.percentile(99.0),
        };
        lag_summary.report();
        results.push(lag_summary);
    }

    // Recluster cost: one full membership plan over a churned population
    // (z-score + per-region balanced k-means + departed parking) — what
    // an Event::Recluster pays beyond re-profiling. No artifacts needed.
    for &n in &[1_000usize, 10_000] {
        let m = 16usize;
        let m_cn = 10usize;
        let edge_regions: Vec<Region> = (0..m)
            .map(|j| if j < m_cn { Region::Cn } else { Region::Us })
            .collect();
        let n_cn = n * 6 / 10;
        let device_regions: Vec<Region> = (0..n)
            .map(|d| if d < n_cn { Region::Cn } else { Region::Us })
            .collect();
        let current: Vec<usize> = (0..n)
            .map(|d| {
                if d < n_cn {
                    d % m_cn
                } else {
                    m_cn + d % (m - m_cn)
                }
            })
            .collect();
        let mut setup = Rng::new(99);
        // ~75% of the population is live; plenty per region at n >= 1k.
        let live: Vec<usize> =
            (0..n).filter(|_| setup.uniform() < 0.75).collect();
        let features: Vec<Vec<f64>> = live
            .iter()
            .map(|&d| {
                (0..5)
                    .map(|_| setup.uniform() * 10.0 + (d % 7) as f64)
                    .collect()
            })
            .collect();
        results.push(bench(
            &format!("membership/plan_recluster/{n}"),
            || {
                let mut rng = Rng::new(7);
                let plan = plan_recluster(
                    &live,
                    &features,
                    &device_regions,
                    &edge_regions,
                    &current,
                    &mut rng,
                )
                .expect("feasible population");
                black_box(plan.migrated.len());
            },
        ));
    }

    if let Err(e) = write_json(&results) {
        eprintln!("warning: could not write BENCH_event_queue.json: {e}");
    }
}

/// Record the run at the repo root (benches run with CWD = rust/).
fn write_json(results: &[BenchResult]) -> std::io::Result<()> {
    let mut root = BTreeMap::new();
    root.insert(
        "generated_by".to_string(),
        Json::Str("cargo bench --bench event_queue".into()),
    );
    root.insert(
        "note".to_string(),
        Json::Str(
            "per-iteration ns; transfer_heavy/transfer_repredict are the \
             event-queue scale-out baselines (ROADMAP); churn_heavy and \
             membership/plan_recluster record the re-clustering-on-churn \
             cost; drain_bare vs drain_observed is the observer-overhead \
             pair (dequeue_lag_ns percentiles come straight from the \
             obs::Histogram); push_pop/{backend} is the Copy-event \
             re-push hot path per queue backend; sharded_sim/workers/W \
             is per-event ns of the sharded 1M+-device engine (65k \
             under ARENA_BENCH_FAST) and threads_speedup/W stores the \
             run(1)/run(W) wall ratio — dimensionless — in mean_ns; \
             engine_loop/workers/W and engine_loop/threads_speedup/W \
             are the same pair for the full engine-shard event loop \
             (semi-sync + over-selection + churn + fault storm, \
             trajectory asserted byte-identical across W); \
             sharded_sim/profiled/W is the same engine with the \
             per-shard profiler + RunObserver attached, \
             profiler_overhead/W stores the profiled/bare wall ratio \
             (dimensionless, <1.05 target) in mean_ns, \
             barrier_stall_ns/W carries the profiled run's \
             barrier-arrival spread percentiles and \
             shard_imbalance_x1000/W the final max/mean-events gauge \
             scaled by 1000; fault_storm/W is per-event ns of the \
             sharded engine under injected outage+partition+crash \
             faults (trajectory asserted byte-identical across W)"
                .into(),
        ),
    );
    let mut arr = Vec::new();
    for r in results {
        let mut e = BTreeMap::new();
        e.insert("name".to_string(), Json::Str(r.name.clone()));
        e.insert("iters".to_string(), Json::Num(r.iters as f64));
        e.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
        e.insert("p50_ns".to_string(), Json::Num(r.p50_ns));
        e.insert("p99_ns".to_string(), Json::Num(r.p99_ns));
        arr.push(Json::Obj(e));
    }
    root.insert("results".to_string(), Json::Arr(arr));
    let path = if std::path::Path::new("../BENCH_event_queue.json").exists()
        || std::path::Path::new("../ROADMAP.md").exists()
    {
        "../BENCH_event_queue.json"
    } else {
        "BENCH_event_queue.json"
    };
    std::fs::write(path, Json::Obj(root).to_pretty())
}
