//! Bench: the discrete-event scheduler under asynchronous-HFL load —
//! ≥10k device events scheduled and drained per iteration (the target is
//! that the queue never shows up in an async-run profile next to real
//! training). Coarse timestamps force heavy tie-break traffic, the worst
//! case for the seeded ordering. No artifacts needed.
//! `cargo bench --bench event_queue`

use arena::sim::{Event, EventQueue};
use arena::util::microbench::{bench, black_box};

fn main() {
    for &n in &[10_000usize, 100_000] {
        bench(&format!("event_queue/schedule+drain/{n}"), || {
            let mut q = EventQueue::new(42);
            for i in 0..n {
                // ~500 distinct timestamps -> ~n/500 ties per slot.
                let t = ((i * 7919) % 500) as f64 * 0.25;
                q.schedule(
                    t,
                    Event::DeviceTrainDone {
                        device: i % 10_000,
                        edge: i % 8,
                    },
                );
            }
            let mut last = -1.0f64;
            while let Some((t, ev)) = q.pop() {
                debug_assert!(t >= last);
                last = t;
                black_box(ev);
            }
            black_box(last);
        });

        // Steady-state churn: the queue holds n events while each pop
        // reschedules one — the async engine's actual access pattern.
        bench(&format!("event_queue/steady_state/{n}"), || {
            let mut q = EventQueue::new(7);
            for i in 0..n {
                q.schedule(
                    (i % 500) as f64,
                    Event::DeviceTrainDone {
                        device: i,
                        edge: i % 8,
                    },
                );
            }
            for _ in 0..n {
                let (t, ev) = q.pop().unwrap();
                q.schedule(t + 500.0, ev);
            }
            black_box(q.len());
        });
    }
}
