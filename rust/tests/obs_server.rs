//! Loopback tests of the telemetry stack through the public API only.
//!
//! The `obs` subsystem is simulation-independent, so unlike
//! `tests/integration.rs` these need no compiled artifacts: a
//! [`arena::obs::RunObserver`] is fed synthetic hook calls and the
//! served endpoints are scraped over 127.0.0.1.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

use arena::hfl::{EdgeStats, RoundStats};
use arena::obs::server::http_get;
use arena::obs::{Observer, RunObserver, TelemetryServer};

fn stats(k: usize) -> RoundStats {
    RoundStats {
        k,
        accuracy: 0.5 + 0.01 * k as f64,
        test_loss: 0.9,
        train_loss: 0.8,
        round_time: 60.0,
        sim_now: 60.0 * k as f64,
        per_edge: vec![EdgeStats::default(); 2],
        energy: 3.0,
        gamma1: vec![1, 1],
        gamma2: vec![1, 1],
        device_losses: vec![],
        n_reclusters: 0,
        migrated_devices: 0,
        active_devices: 6,
        edge_size_imbalance: 0.0,
        live_model_buffers: 3,
        peak_model_bytes: 4096,
        sharing_ratio: 1.0,
        fault_events: 0,
    }
}

/// Full-body GET for the connection-closing endpoints (`/healthz`,
/// `/metrics`, 404). `/stream` keeps its connection open — probe that
/// one with [`http_get`], which returns after the first frame line.
fn get_full(addr: &SocketAddr, path: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
    s.flush().unwrap();
    let mut buf = String::new();
    s.read_to_string(&mut buf).unwrap();
    buf
}

#[test]
fn observer_publishes_scrapeable_telemetry() {
    let server = TelemetryServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let mut obs = RunObserver::with_sink(server.sink());

    obs.on_event_handled("train_done", 5.0, 120, 8_000);
    obs.on_transfer(0, "up", 1.0e6, 5.0, 9.0);
    obs.on_round(&stats(1));
    obs.on_round(&stats(2));

    let health = get_full(&addr, "/healthz");
    assert!(health.starts_with("HTTP/1.1 200"), "{health}");
    assert!(health.contains("ok"), "{health}");

    // `/metrics` serves the exposition the observer published at the
    // last closed round (set_metrics is synchronous — no pump race).
    let metrics = get_full(&addr, "/metrics");
    assert!(metrics.starts_with("HTTP/1.1 200"), "{metrics}");
    assert!(
        metrics.contains("text/plain; version=0.0.4"),
        "{metrics}"
    );
    assert!(metrics.contains("arena_events_total 1"), "{metrics}");
    assert!(metrics.contains("arena_rounds_total 2"), "{metrics}");
    assert!(metrics.contains("arena_round_accuracy"), "{metrics}");
    assert!(
        metrics.contains("arena_event_dequeue_lag_ns_bucket"),
        "{metrics}"
    );

    // A subscriber connecting after the last round still gets the
    // latched final frame (what keeps a post-run `curl /stream`
    // useful). The latch is filled by the pump thread — retry briefly.
    let mut frame = String::new();
    for _ in 0..100 {
        frame = http_get(&addr, "/stream", 1 << 20).unwrap_or_default();
        if frame.contains("\"type\":\"round\"") {
            break;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    let body = frame
        .lines()
        .find(|l| l.starts_with('{'))
        .expect("no NDJSON frame in /stream response");
    let j = arena::util::json::Json::parse(body).unwrap();
    assert_eq!(j.get("type").unwrap().as_str().unwrap(), "round");
    assert_eq!(j.get("k").unwrap().as_usize().unwrap(), 2);
    assert!(j.get("schema_version").is_some());

    let missing = get_full(&addr, "/nope");
    assert!(missing.starts_with("HTTP/1.1 404"), "{missing}");

    server.stop();
}

#[test]
fn dashboard_is_served_at_root() {
    let server = TelemetryServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();
    let page = get_full(&addr, "/");
    assert!(page.starts_with("HTTP/1.1 200"), "{page}");
    assert!(page.contains("text/html"), "{page}");
    assert!(page.contains("arena dashboard"), "{page}");
    // Self-contained live view: it must consume the sibling endpoints
    // (streamed frames + scraped exposition), not bundle data.
    assert!(page.contains("fetch(\"/stream\")"), "{page}");
    assert!(page.contains("fetch(\"/metrics\")"), "{page}");
    assert!(page.contains("shard_window"), "{page}");
    // /index.html is the same document.
    let alias = get_full(&addr, "/index.html");
    assert!(alias.contains("arena dashboard"), "{alias}");
    server.stop();
}

#[test]
fn trace_endpoint_serves_current_trace_json() {
    let server = TelemetryServer::bind("127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    // Before any publish: an empty but valid Chrome trace document.
    let empty = get_full(&addr, "/trace");
    assert!(empty.starts_with("HTTP/1.1 200"), "{empty}");
    assert!(empty.contains("application/json"), "{empty}");
    assert!(empty.contains("{\"traceEvents\":[]}"), "{empty}");

    // After the observer publishes: the live spans, parseable JSON.
    let mut obs = RunObserver::with_sink(server.sink());
    obs.on_transfer(0, "up", 1.0e6, 5.0, 9.0);
    let state = obs.state();
    let json = state.lock().unwrap().trace.to_chrome_json();
    server.sink().set_trace(json);
    let live = get_full(&addr, "/trace");
    let body = live
        .lines()
        .find(|l| l.starts_with('{'))
        .expect("no JSON body in /trace response");
    let j = arena::util::json::Json::parse(body).unwrap();
    let events = j.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "{live}");
    server.stop();
}

#[test]
fn trace_export_covers_observed_spans() {
    let mut obs = RunObserver::new();
    obs.on_transfer(1, "down", 2.0e6, 10.0, 14.0);
    obs.on_round(&stats(1));
    let state = obs.state();
    let st = state.lock().unwrap();
    assert_eq!(st.trace.len(), 2);
    let json = st.trace.to_chrome_json();
    assert!(json.contains("\"xfer down\""), "{json}");
    assert!(json.contains("\"window 1\""), "{json}");
    // Chrome-trace ts is microseconds of sim time: 10 s -> 10_000_000.
    assert!(json.contains("\"ts\":10000000"), "{json}");
}
