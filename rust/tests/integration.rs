//! Integration tests over the real AOT artifacts (requires `make artifacts`).
//!
//! These exercise the full rust↔PJRT path: artifact loading, execution,
//! numerics against CPU references, and whole HFL rounds.

use arena::config::{ExperimentConfig, SyncModeCfg};
use arena::hfl::{AsyncHflEngine, HflEngine};
use arena::runtime::{HostTensor, Runtime};
use arena::sim::QueueBackend;
use arena::util::rng::Rng;

fn artifacts_dir() -> String {
    std::env::var("ARENA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into())
}

fn have_artifacts() -> bool {
    std::path::Path::new(&artifacts_dir())
        .join("manifest.json")
        .exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
            return;
        }
    };
}

fn small_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist();
    cfg.topology.devices = 10;
    cfg.topology.edges = 5;
    cfg.hfl.samples_per_device = 128;
    cfg.hfl.threshold_time = 400.0;
    cfg.workers = 2;
    cfg.artifacts_dir = artifacts_dir();
    cfg
}

#[test]
fn aggregate_artifact_matches_cpu_reference() {
    require_artifacts!();
    let rt = Runtime::load(artifacts_dir(), &["mnist_aggregate"]).unwrap();
    let p = rt.manifest.param_count("mnist").unwrap();
    let nmax = rt.manifest.config.nmax;
    let mut rng = Rng::new(1);
    let mut models = vec![0.0f32; nmax * p];
    let mut weights = vec![0.0f32; nmax];
    for i in 0..3 {
        for j in 0..p {
            models[i * p + j] = rng.normal() as f32;
        }
        weights[i] = (i + 1) as f32;
    }
    let out = rt
        .execute(
            "mnist_aggregate",
            &[
                HostTensor::f32(vec![nmax, p], models.clone()),
                HostTensor::f32(vec![nmax], weights.clone()),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();
    let wsum: f32 = weights.iter().sum();
    for j in (0..p).step_by(997) {
        let want: f32 = (0..3)
            .map(|i| weights[i] * models[i * p + j])
            .sum::<f32>()
            / wsum;
        assert!(
            (got[j] - want).abs() < 1e-4,
            "j={j}: {} vs {want}",
            got[j]
        );
    }
}

#[test]
fn eval_artifact_shapes_and_range() {
    require_artifacts!();
    let rt = Runtime::load(artifacts_dir(), &["mnist_eval"]).unwrap();
    let p = rt.manifest.param_count("mnist").unwrap();
    let w = rt.load_init_params("mnist").unwrap();
    assert_eq!(w.len(), p);
    let ts = rt.manifest.config.test_size;
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..ts * 28 * 28).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..ts).map(|i| (i % 10) as i32).collect();
    let out = rt
        .execute(
            "mnist_eval",
            &[
                HostTensor::f32(vec![p], w),
                HostTensor::f32(vec![ts, 28, 28, 1], x),
                HostTensor::i32(vec![ts], y),
            ],
        )
        .unwrap();
    let correct = out[0].scalar().unwrap();
    assert!((0.0..=ts as f64).contains(&correct), "correct={correct}");
    assert!(out[1].scalar().unwrap() > 0.0, "loss must be positive");
}

#[test]
fn shape_mismatch_is_rejected() {
    require_artifacts!();
    let rt = Runtime::load(artifacts_dir(), &["mnist_aggregate"]).unwrap();
    let bad = rt.execute(
        "mnist_aggregate",
        &[
            HostTensor::f32(vec![2, 3], vec![0.0; 6]),
            HostTensor::f32(vec![2], vec![1.0; 2]),
        ],
    );
    assert!(bad.is_err());
}

#[test]
fn ppo_artifacts_roundtrip() {
    require_artifacts!();
    let rt =
        Runtime::load(artifacts_dir(), &["ppo_actor_fwd", "ppo_update"])
            .unwrap();
    let agent = arena::agent::PpoAgent::new(&rt).unwrap();
    let state = vec![0.1f32; agent.state_len()];
    let mut rng = Rng::new(3);
    let (raw, logp, value) = agent.act(&rt, &state, &mut rng).unwrap();
    assert_eq!(raw.len(), agent.act_len());
    assert!(logp.is_finite() && value.is_finite());

    // A PPO update with a tiny synthetic batch must change parameters.
    let mut agent = agent;
    let b = agent.batch();
    let traj = {
        let mut t = arena::agent::Trajectory::default();
        for i in 0..4 {
            t.push(arena::agent::Transition {
                state: state.clone(),
                raw_action: raw.clone(),
                log_prob: logp,
                value,
                reward: i as f64,
            });
        }
        t
    };
    let (adv, ret) = arena::agent::gae_advantages(
        &traj.rewards(),
        &traj.values(),
        0.9,
        0.9,
    );
    let batch =
        traj.to_batch(&adv, &ret, b, agent.state_len(), agent.act_len());
    let before = agent.theta.clone();
    let losses = agent.update(&rt, &batch).unwrap();
    assert!(losses.policy.is_finite());
    assert!(agent.theta != before, "update must move parameters");
}

#[test]
fn engine_round_trains_and_accounts() {
    require_artifacts!();
    let cfg = small_cfg();
    let mut engine = HflEngine::new(cfg, true).unwrap();
    let m = engine.edges();
    let (acc0, _) = engine.evaluate().unwrap();
    let stats = engine
        .run_round(&vec![2; m], &vec![1; m], None)
        .unwrap();
    assert_eq!(stats.k, 1);
    assert!(stats.round_time > 0.0);
    assert!(stats.energy > 0.0);
    assert!(stats.accuracy >= 0.0 && stats.accuracy <= 1.0);
    assert_eq!(stats.per_edge.len(), m);
    for e in &stats.per_edge {
        assert!(e.active > 0);
        assert!(e.t_ec > 0.0);
        assert!(e.t_sgd_slowest > 0.0);
    }
    // Model-store observables: right after the round's broadcast every
    // device handle shares the cloud buffer — the O(N·p) clone wall is
    // gone and the history rows can prove it.
    assert!(
        stats.sharing_ratio > 0.9,
        "post-broadcast sharing_ratio {} <= 0.9",
        stats.sharing_ratio
    );
    assert!(
        stats.live_model_buffers <= 1 + m,
        "live buffers {} exceed 1 cloud + {m} edges",
        stats.live_model_buffers
    );
    assert!(stats.peak_model_bytes > 0);
    // Training from synthetic-learnable data should beat random-init acc
    // within a few rounds.
    let mut acc = stats.accuracy;
    for _ in 0..3 {
        acc = engine
            .run_round(&vec![2; m], &vec![1; m], None)
            .unwrap()
            .accuracy;
    }
    assert!(
        acc > acc0 + 0.1,
        "no learning signal: init {acc0}, after 4 rounds {acc}"
    );
}

#[test]
fn engine_reset_restores_initial_state() {
    require_artifacts!();
    let cfg = small_cfg();
    let mut engine = HflEngine::new(cfg, false).unwrap();
    let w0 = engine.cloud_model().to_vec();
    let m = engine.edges();
    engine.run_round(&vec![1; m], &vec![1; m], None).unwrap();
    assert!(engine.cloud_model() != w0.as_slice());
    engine.reset();
    assert_eq!(engine.cloud_model(), w0.as_slice());
    assert_eq!(engine.round, 0);
    assert_eq!(engine.clock.now(), 0.0);
    // Reset collapses the whole hierarchy back onto one shared buffer.
    assert_eq!(engine.store.live_buffers(), 1);
}

#[test]
fn participation_mask_limits_training() {
    require_artifacts!();
    let cfg = small_cfg();
    let mut engine = HflEngine::new(cfg.clone(), false).unwrap();
    let m = engine.edges();
    let n = cfg.topology.devices;
    let mut mask = vec![false; n];
    for (i, b) in mask.iter_mut().enumerate() {
        *b = i % 2 == 0;
    }
    let stats = engine
        .run_round(&vec![1; m], &vec![1; m], Some(&mask))
        .unwrap();
    let active: usize = stats.per_edge.iter().map(|e| e.active).sum();
    assert_eq!(active, n / 2);
    assert_eq!(stats.device_losses.len(), n / 2);
    for (dev, _) in &stats.device_losses {
        assert!(mask[*dev]);
    }
}

#[test]
fn cifar_engine_round_works() {
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.hfl.dataset = arena::config::Dataset::Cifar;
    cfg.sim.sgd_base_time = 8.0;
    let mut engine = HflEngine::new(cfg, false).unwrap();
    let m = engine.edges();
    let stats = engine.run_round(&vec![1; m], &vec![1; m], None).unwrap();
    assert!(stats.accuracy >= 0.0 && stats.accuracy <= 1.0);
    assert!(stats.round_time > 0.0);
    // CIFAR-shape rounds must be slower than MNIST-shape in simulated time
    // (4x per-batch base cost).
    assert!(stats.round_time > 10.0);
}

#[test]
fn npca_variant_agents_load_and_act() {
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir(), &[]).unwrap();
    for npca in [2usize, 10] {
        let agent = arena::agent::PpoAgent::new_variant(&rt, npca).unwrap();
        let (fwd, _) = agent.artifact_names();
        rt.compile(&fwd).unwrap();
        let state = vec![0.05f32; agent.state_len()];
        let mut rng = Rng::new(9);
        let (raw, logp, _) = agent.act(&rt, &state, &mut rng).unwrap();
        assert_eq!(raw.len(), agent.act_len());
        assert!(logp.is_finite(), "npca={npca}");
    }
}

#[test]
fn share_reassignment_keeps_regions_and_balance() {
    require_artifacts!();
    let cfg = small_cfg();
    let engine = HflEngine::new(cfg.clone(), false).unwrap();
    let assignment = arena::baselines::share::share_assignment(&engine);
    assert_eq!(assignment.len(), cfg.topology.devices);
    // Same cluster sizes as before (swap-only search).
    let mut sizes = vec![0usize; cfg.topology.edges];
    for &e in &assignment {
        sizes[e] += 1;
    }
    for (j, edge) in engine.topo.edges.iter().enumerate() {
        assert_eq!(sizes[j], edge.members.len(), "size changed at edge {j}");
    }
    // Region constraint respected.
    for (dev, &e) in assignment.iter().enumerate() {
        assert_eq!(
            engine.topo.edges[e].region,
            engine.topo.device_regions[dev],
            "device {dev} crossed regions"
        );
    }
}

#[test]
fn var_freq_frequencies_within_bounds() {
    require_artifacts!();
    let cfg = small_cfg();
    let engine = HflEngine::new(cfg.clone(), true).unwrap();
    for (g1, g2) in [
        arena::baselines::var_freq::var_freq_a_frequencies(&engine),
        arena::baselines::var_freq::var_freq_b_frequencies(&engine),
    ] {
        assert_eq!(g1.len(), cfg.topology.edges);
        for j in 0..g1.len() {
            assert!((1..=cfg.hfl.gamma1_max).contains(&g1[j]));
            assert!((1..=cfg.hfl.gamma2_max).contains(&g2[j]));
        }
    }
    // A gives the fastest edge at least as much work as the slowest edge.
    let (g1, _) = arena::baselines::var_freq::var_freq_a_frequencies(&engine);
    let times: Vec<f64> = (0..engine.edges())
        .map(|j| engine.predict_edge_time(j, 1, 1))
        .collect();
    let fastest = times
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    let slowest = times
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert!(g1[fastest] >= g1[slowest], "g1={g1:?}, times={times:?}");
}

#[test]
fn predict_edge_time_monotone_in_frequencies() {
    require_artifacts!();
    let engine = HflEngine::new(small_cfg(), false).unwrap();
    for j in 0..engine.edges() {
        let t11 = engine.predict_edge_time(j, 1, 1);
        let t51 = engine.predict_edge_time(j, 5, 1);
        let t54 = engine.predict_edge_time(j, 5, 4);
        assert!(t11 < t51 && t51 < t54, "edge {j}: {t11} {t51} {t54}");
    }
}

#[test]
fn mobility_limits_participants() {
    require_artifacts!();
    let cfg = small_cfg();
    let mut engine = HflEngine::new(cfg.clone(), false).unwrap();
    engine.mobility = arena::sim::MobilityModel::new(
        cfg.topology.devices,
        1.0, // everyone leaves after round 1
        0.0,
        Rng::new(5),
    );
    let m = engine.edges();
    let s1 = engine.run_round(&vec![1; m], &vec![1; m], None).unwrap();
    let a1: usize = s1.per_edge.iter().map(|e| e.active).sum();
    assert_eq!(a1, cfg.topology.devices);
    let s2 = engine.run_round(&vec![1; m], &vec![1; m], None).unwrap();
    let a2: usize = s2.per_edge.iter().map(|e| e.active).sum();
    assert!(a2 <= 1, "after mass departure only the keep-alive remains");
}

#[test]
fn async_engine_sync_mode_matches_run_round_bit_for_bit() {
    require_artifacts!();
    let cfg = small_cfg();
    let mut barrier = HflEngine::new(cfg.clone(), true).unwrap();
    let mut events = AsyncHflEngine::new(cfg, true).unwrap();
    let m = barrier.edges();
    let g1 = vec![2; m];
    let g2 = vec![2; m];
    for k in 0..3 {
        let a = barrier.run_round(&g1, &g2, None).unwrap();
        let b = events.run_round(&g1, &g2, None).unwrap();
        // Same seed, same RNG streams, same arithmetic: the event-driven
        // timeline must reproduce the barrier engine exactly, not just
        // approximately.
        assert_eq!(a.accuracy, b.accuracy, "accuracy diverged at round {k}");
        assert_eq!(a.round_time, b.round_time, "time diverged at round {k}");
        assert_eq!(a.energy, b.energy, "energy diverged at round {k}");
        assert_eq!(a.train_loss, b.train_loss);
        assert_eq!(a.sim_now, b.sim_now);
        for j in 0..m {
            assert_eq!(a.per_edge[j].total_time, b.per_edge[j].total_time);
            assert_eq!(a.per_edge[j].t_ec, b.per_edge[j].t_ec);
            assert_eq!(a.per_edge[j].active, b.per_edge[j].active);
        }
    }
    assert_eq!(
        barrier.cloud_model(),
        events.eng.cloud_model(),
        "models diverged"
    );
}

#[test]
fn async_engine_sync_mode_matches_under_churn_and_mask() {
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.sim.leave_prob = 0.2;
    cfg.sim.join_prob = 0.5;
    // Also under churn-driven re-clustering: both engines run it through
    // the same HflEngine path at the same point of the round.
    cfg.cluster.recluster_threshold = 0.15;
    cfg.cluster.recluster_min_interval = 0.0;
    let mut barrier = HflEngine::new(cfg.clone(), false).unwrap();
    let mut events = AsyncHflEngine::new(cfg.clone(), false).unwrap();
    let m = barrier.edges();
    let n = cfg.topology.devices;
    let mask: Vec<bool> = (0..n).map(|d| d % 3 != 0).collect();
    let g1 = vec![2; m];
    let g2 = vec![1; m];
    for _ in 0..3 {
        let a = barrier.run_round(&g1, &g2, Some(&mask)).unwrap();
        let b = events.run_round(&g1, &g2, Some(&mask)).unwrap();
        assert_eq!(a.accuracy, b.accuracy);
        assert_eq!(a.round_time, b.round_time);
        assert_eq!(a.energy, b.energy);
    }
}

#[test]
fn zero_fault_plan_is_a_bitwise_noop_under_churn() {
    // Sixth determinism guarantee (hfl::lifecycle): a `FaultPlan` with
    // zero event counts schedules nothing and draws nothing — a run
    // with inert fault knobs set (non-default durations, zero counts)
    // must be BITWISE identical to the same run with the fault config
    // untouched, on the churn + mask + recluster workload above.
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.sim.leave_prob = 0.2;
    cfg.sim.join_prob = 0.5;
    cfg.cluster.recluster_threshold = 0.15;
    cfg.cluster.recluster_min_interval = 0.0;
    let mut inert = cfg.clone();
    inert.fault.outage_duration = 999.0;
    inert.fault.partition_duration = 777.0;
    inert.fault.rejoin_delay = 13.0;
    inert.fault.crash_frac = 0.9;
    assert_eq!(inert.fault.outages, 0, "counts stay zero");
    // Event loop (the path that expands the FaultPlan in begin_run):
    // churned semi-sync runs, full-history comparison.
    cfg.hfl.threshold_time = 500.0;
    inert.hfl.threshold_time = 500.0;
    cfg.sync.mode = SyncModeCfg::SemiSync;
    inert.sync.mode = SyncModeCfg::SemiSync;
    cfg.sync.cloud_interval = 120.0;
    inert.sync.cloud_interval = 120.0;
    let mut base = AsyncHflEngine::new(cfg, false).unwrap();
    let mut faulted = AsyncHflEngine::new(inert, false).unwrap();
    let ha = base.run_to_threshold().unwrap();
    let hb = faulted.run_to_threshold().unwrap();
    assert_eq!(ha.rounds.len(), hb.rounds.len(), "window count diverged");
    for (a, b) in ha.rounds.iter().zip(&hb.rounds) {
        assert_eq!(a.accuracy, b.accuracy, "accuracy diverged at {}", a.k);
        assert_eq!(a.round_time, b.round_time, "time diverged at {}", a.k);
        assert_eq!(a.energy, b.energy, "energy diverged at {}", a.k);
        assert_eq!(a.sim_now, b.sim_now);
        assert_eq!(a.fault_events, 0);
        assert_eq!(b.fault_events, 0, "inert plan injected an event");
        for (ea, eb) in a.per_edge.iter().zip(&b.per_edge) {
            assert_eq!(ea.total_time, eb.total_time);
            assert_eq!(ea.active, eb.active);
            assert_eq!(ea.abandoned, eb.abandoned);
            assert_eq!(ea.availability, eb.availability);
        }
    }
    assert_eq!(
        base.eng.cloud_model(),
        faulted.eng.cloud_model(),
        "zero-fault plan perturbed the model"
    );
}

#[test]
fn semi_sync_and_async_modes_run_end_to_end() {
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.hfl.threshold_time = 500.0;
    cfg.sync.cloud_interval = 120.0;
    for mode in [SyncModeCfg::SemiSync, SyncModeCfg::Async] {
        let mut c = cfg.clone();
        c.sync.mode = mode;
        let mut e = AsyncHflEngine::new(c, false).unwrap();
        let hist = e.run_to_threshold().unwrap();
        assert!(
            !hist.rounds.is_empty(),
            "{mode:?}: no cloud windows completed"
        );
        assert!(hist.total_energy() > 0.0, "{mode:?}: no energy accounted");
        for r in &hist.rounds {
            assert!(r.accuracy >= 0.0 && r.accuracy <= 1.0);
            assert!(r.round_time > 0.0);
            // At least one edge aggregation per window once training flows.
            let aggs: usize = r.gamma2.iter().sum();
            assert!(aggs > 0, "{mode:?}: window {} had no edge aggs", r.k);
            // Memory observables flow through the event engine too.
            assert!(r.live_model_buffers >= 1, "{mode:?}");
            assert!(r.peak_model_bytes > 0, "{mode:?}");
            assert!(
                (0.0..=1.0).contains(&r.sharing_ratio),
                "{mode:?}: sharing_ratio {}",
                r.sharing_ratio
            );
        }
        // Event-driven runs advance the simulated clock through windows.
        assert!(hist.total_time() > 0.0);
    }
}

#[test]
fn semi_sync_quorum_survives_membership_below_quorum() {
    // Liveness regression (transfer-layer PR): churn devices until edge
    // membership drops below sync.quorum. Before the MobilityFlip
    // re-check, an edge whose live set shrank under the outstanding
    // reports could only close at the timer flush; the run must keep
    // closing edge rounds and finishing cloud windows regardless.
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.hfl.threshold_time = 600.0;
    cfg.sync.mode = SyncModeCfg::SemiSync;
    // Quorum equal to full edge membership (2 devices/edge here), heavy
    // one-way churn so live membership falls below it and stays there.
    cfg.sync.quorum = 2;
    cfg.sync.cloud_interval = 150.0;
    cfg.sim.leave_prob = 0.6;
    cfg.sim.join_prob = 0.05;
    let mut e = AsyncHflEngine::new(cfg, false).unwrap();
    let hist = e.run_to_threshold().unwrap();
    assert!(
        !hist.rounds.is_empty(),
        "churned semi-sync run produced no cloud windows"
    );
    let total_aggs: usize = hist
        .rounds
        .iter()
        .map(|r| r.gamma2.iter().sum::<usize>())
        .sum();
    assert!(
        total_aggs > 0,
        "no edge round ever closed under churn (quorum deadlock)"
    );
}

#[test]
fn transfer_path_is_deterministic_under_contention() {
    // Same seed ⇒ identical TransferDone landing order and identical
    // RunHistory, in both event-driven modes, with fair-share contention
    // and churn enabled.
    require_artifacts!();
    for mode in [SyncModeCfg::SemiSync, SyncModeCfg::Async] {
        let mut cfg = small_cfg();
        cfg.hfl.threshold_time = 500.0;
        cfg.sync.mode = mode;
        cfg.sync.quorum = 1; // frequent quorums -> overlapping uploads
        cfg.sync.cloud_interval = 100.0;
        cfg.link.contention = true;
        cfg.sim.leave_prob = 0.1;
        cfg.sim.join_prob = 0.5;
        let run = |cfg: &ExperimentConfig| {
            let mut e = AsyncHflEngine::new(cfg.clone(), false).unwrap();
            let hist = e.run_to_threshold().unwrap();
            (e.transfer_log.clone(), hist)
        };
        let (log_a, hist_a) = run(&cfg);
        let (log_b, hist_b) = run(&cfg);
        assert!(
            !log_a.is_empty(),
            "{mode:?}: no transfers landed at all"
        );
        assert_eq!(
            log_a, log_b,
            "{mode:?}: TransferDone ordering diverged across identical runs"
        );
        assert_eq!(hist_a.rounds.len(), hist_b.rounds.len());
        for (ra, rb) in hist_a.rounds.iter().zip(&hist_b.rounds) {
            assert_eq!(ra.accuracy, rb.accuracy, "{mode:?}");
            assert_eq!(ra.energy, rb.energy, "{mode:?}");
            assert_eq!(ra.round_time, rb.round_time, "{mode:?}");
            for (ea, eb) in ra.per_edge.iter().zip(&rb.per_edge) {
                assert_eq!(ea.t_up, eb.t_up, "{mode:?}");
                assert_eq!(ea.t_down, eb.t_down, "{mode:?}");
                assert_eq!(ea.comm_overlap, eb.comm_overlap, "{mode:?}");
            }
        }
    }
}

#[test]
fn overlap_is_realized_in_event_driven_modes() {
    // Acceptance: with contention enabled, a window's wall-clock must
    // undercut the lump model's serialized compute+comm charge for some
    // edge — i.e. uploads actually ran while devices trained.
    require_artifacts!();
    for mode in [SyncModeCfg::SemiSync, SyncModeCfg::Async] {
        let mut cfg = small_cfg();
        cfg.hfl.threshold_time = 600.0;
        cfg.sync.mode = mode;
        cfg.sync.quorum = 1;
        cfg.sync.cloud_interval = 120.0;
        cfg.link.contention = true;
        let mut e = AsyncHflEngine::new(cfg, false).unwrap();
        let hist = e.run_to_threshold().unwrap();
        let mut saw_overlap = false;
        let mut beat_lump = false;
        for r in &hist.rounds {
            if r.comm_overlap_frac() > 0.0 {
                saw_overlap = true;
            }
            for edge in &r.per_edge {
                // The lump model charges compute + comm serially; the
                // busy-union wall-clock of the edge must beat it whenever
                // any overlap happened (and can never exceed the window).
                let lump = edge.compute_busy + edge.comm_busy;
                assert!(
                    edge.total_time <= r.round_time + 1e-6,
                    "{mode:?}: busy union {} exceeds window {}",
                    edge.total_time,
                    r.round_time
                );
                if lump > edge.total_time + 1e-9 {
                    beat_lump = true;
                    assert!(
                        edge.comm_overlap > 0.0,
                        "{mode:?}: wall-clock beat the lump sum without \
                         recorded overlap"
                    );
                }
            }
        }
        assert!(saw_overlap, "{mode:?}: no window overlapped comm/compute");
        assert!(
            beat_lump,
            "{mode:?}: no edge's wall-clock beat the serialized \
             compute+comm sum"
        );
    }
}

#[test]
fn ctrl_features_deterministic_and_recorded() {
    // The per-edge control observables (staleness of the last landed
    // upload, in-flight uploads, semi-sync quorum fill) must replay
    // bit-for-bit from the experiment seed and stay well-formed. A very
    // narrow, contended uplink makes uploads outlive windows so the
    // signals actually move.
    require_artifacts!();
    for mode in [SyncModeCfg::SemiSync, SyncModeCfg::Async] {
        let mut cfg = small_cfg();
        cfg.hfl.threshold_time = 500.0;
        cfg.sync.mode = mode;
        cfg.sync.quorum = 1;
        cfg.sync.cloud_interval = 60.0;
        cfg.link.up_bandwidth_scale = 0.002;
        cfg.link.contention = true;
        let run = |cfg: &ExperimentConfig| {
            let mut e = AsyncHflEngine::new(cfg.clone(), false).unwrap();
            let hist = e.run_to_threshold().unwrap();
            hist.rounds
                .iter()
                .map(|r| {
                    r.per_edge
                        .iter()
                        .map(|e| (e.staleness, e.in_flight_up, e.quorum_fill))
                        .collect::<Vec<_>>()
                })
                .collect::<Vec<_>>()
        };
        let a = run(&cfg);
        let b = run(&cfg);
        assert_eq!(a, b, "{mode:?}: control features diverged across runs");
        let mut moved = false;
        for round in &a {
            for &(staleness, in_flight, fill) in round {
                assert!(staleness >= 0.0 && staleness.is_finite());
                assert!(fill >= 0.0 && fill.is_finite());
                if staleness > 0.0 || in_flight > 0 || fill > 0.0 {
                    moved = true;
                }
            }
        }
        assert!(
            moved,
            "{mode:?}: no control signal ever left zero under a narrow \
             contended uplink"
        );
        if mode == SyncModeCfg::Async {
            for round in &a {
                for &(_, _, fill) in round {
                    assert_eq!(fill, 0.0, "quorum fill is semi-sync only");
                }
            }
        }
    }
}

#[test]
fn rearming_fixed_knobs_is_bitwise_noop() {
    // Zero churn, fixed knobs: stepping the run window-by-window and
    // re-arming (γ1_j, α_j) with the values already in force at every
    // cloud decision point must reproduce the single-call run
    // bit-for-bit — transfer timeline, stats, and final model.
    require_artifacts!();
    for mode in [SyncModeCfg::SemiSync, SyncModeCfg::Async] {
        let mut cfg = small_cfg();
        cfg.hfl.threshold_time = 500.0;
        cfg.sync.mode = mode;
        cfg.sync.cloud_interval = 120.0;
        let m = cfg.topology.edges;
        let g1 = vec![2usize; m];
        let alpha = vec![cfg.sync.staleness_alpha; m];

        let mut plain = AsyncHflEngine::new(cfg.clone(), false).unwrap();
        let hist_a = plain.run_with(&g1).unwrap();

        let mut stepped = AsyncHflEngine::new(cfg.clone(), false).unwrap();
        stepped.begin_run(&g1).unwrap();
        let mut hist_b = Vec::new();
        while let Some(stats) = stepped.run_window().unwrap() {
            hist_b.push(stats);
            // Re-arm with the identical knobs at the decision point.
            stepped.set_control(&g1, &alpha).unwrap();
        }
        assert_eq!(
            plain.transfer_log, stepped.transfer_log,
            "{mode:?}: transfer timeline diverged under re-arming"
        );
        assert_eq!(hist_a.rounds.len(), hist_b.len(), "{mode:?}");
        for (ra, rb) in hist_a.rounds.iter().zip(&hist_b) {
            assert_eq!(ra.accuracy, rb.accuracy, "{mode:?}");
            assert_eq!(ra.energy, rb.energy, "{mode:?}");
            assert_eq!(ra.round_time, rb.round_time, "{mode:?}");
            assert_eq!(ra.sim_now, rb.sim_now, "{mode:?}");
            for (ea, eb) in ra.per_edge.iter().zip(&rb.per_edge) {
                assert_eq!(ea.t_up, eb.t_up, "{mode:?}");
                assert_eq!(ea.staleness, eb.staleness, "{mode:?}");
                assert_eq!(ea.in_flight_up, eb.in_flight_up, "{mode:?}");
            }
        }
        assert_eq!(
            plain.eng.cloud_model(),
            stepped.eng.cloud_model(),
            "{mode:?}: models diverged"
        );
    }
}

#[test]
fn ctrl_agent_roundtrip_if_built() {
    // The _ctrl agent variant (extended control-state layout) loads, acts
    // and updates like the default one. Skips on artifact sets that
    // predate the variant.
    require_artifacts!();
    let mut rt = Runtime::load(artifacts_dir(), &[]).unwrap();
    if !rt.manifest.artifacts.contains_key("ppo_actor_fwd_ctrl") {
        eprintln!("skipping: no ppo_actor_fwd_ctrl (re-run make artifacts)");
        return;
    }
    let agent = arena::agent::PpoAgent::new_ctrl_variant(&rt).unwrap();
    let m = rt.manifest.config.m_edges;
    let npca = rt.manifest.config.npca;
    assert_eq!(agent.state_len(), (m + 1) * (npca + 6));
    assert_eq!(agent.act_len(), 2 * m);
    let (fwd, upd) = agent.artifact_names();
    rt.compile(&fwd).unwrap();
    rt.compile(&upd).unwrap();
    let state = vec![0.1f32; agent.state_len()];
    let mut rng = Rng::new(17);
    let (raw, logp, value) = agent.act(&rt, &state, &mut rng).unwrap();
    assert_eq!(raw.len(), agent.act_len());
    assert!(logp.is_finite() && value.is_finite());
    let mut agent = agent;
    let b = agent.batch();
    let traj = {
        let mut t = arena::agent::Trajectory::default();
        for i in 0..4 {
            t.push(arena::agent::Transition {
                state: state.clone(),
                raw_action: raw.clone(),
                log_prob: logp,
                value,
                reward: i as f64,
            });
        }
        t
    };
    let (adv, ret) = arena::agent::gae_advantages(
        &traj.rewards(),
        &traj.values(),
        0.9,
        0.9,
    );
    let batch =
        traj.to_batch(&adv, &ret, b, agent.state_len(), agent.act_len());
    let before = agent.theta.clone();
    let losses = agent.update(&rt, &batch).unwrap();
    assert!(losses.policy.is_finite());
    assert!(agent.theta != before, "ctrl update must move parameters");
}

#[test]
fn async_modes_are_seed_deterministic() {
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.hfl.threshold_time = 400.0;
    cfg.sync.cloud_interval = 120.0;
    cfg.sync.mode = SyncModeCfg::Async;
    cfg.sim.leave_prob = 0.1;
    cfg.sim.join_prob = 0.5;
    let run = |cfg: &ExperimentConfig| {
        let mut e = AsyncHflEngine::new(cfg.clone(), false).unwrap();
        e.run_to_threshold().unwrap()
    };
    let a = run(&cfg);
    let b = run(&cfg);
    assert_eq!(a.rounds.len(), b.rounds.len());
    for (ra, rb) in a.rounds.iter().zip(&b.rounds) {
        assert_eq!(ra.accuracy, rb.accuracy);
        assert_eq!(ra.energy, rb.energy);
        assert_eq!(ra.round_time, rb.round_time);
    }
}

#[test]
fn recluster_triggers_and_warm_starts_under_churn() {
    // Acceptance (membership subsystem): with churn and an enabled
    // threshold, a run logs >= 1 recluster with migrated_devices > 0,
    // migrated devices hold their new edge's model right after the
    // re-clustering, and the topology stays valid throughout.
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.hfl.threshold_time = 1500.0;
    cfg.sim.leave_prob = 0.3;
    cfg.sim.join_prob = 0.6;
    cfg.cluster.recluster_threshold = 0.1;
    cfg.cluster.recluster_min_interval = 0.0;
    let mut e = HflEngine::new(cfg.clone(), true).unwrap();
    let m = e.edges();
    let n = cfg.topology.devices;
    let mut total_reclusters = 0;
    let mut total_migrated = 0;
    for _ in 0..8 {
        let stats = e.run_round(&vec![1; m], &vec![1; m], None).unwrap();
        total_reclusters += stats.n_reclusters;
        total_migrated += stats.migrated_devices;
        assert_eq!(stats.active_devices, e.mobility.active_count());
        if stats.n_reclusters > 0 {
            let out = e.last_recluster.clone().expect("outcome recorded");
            assert_eq!(stats.migrated_devices, out.migrated.len());
            for &(d, old, new) in &out.migrated {
                assert_ne!(old, new, "non-move listed as migration");
                // Warm start: the migrated device resumed from its new
                // edge's current model — by reference, not by copy.
                assert!(
                    e.device_w[d].shares_buffer_with(&e.edge_w[new]),
                    "device {d} not warm-started from edge {new}"
                );
                assert_eq!(
                    e.model(&e.device_w[d]),
                    e.model(&e.edge_w[new]),
                    "device {d} model differs from edge {new}"
                );
                assert!(e.topo.edges[new].members.contains(&d));
                assert_eq!(
                    e.topo.device_regions[d],
                    e.topo.edges[new].region,
                    "migration crossed regions"
                );
            }
        }
        // The migrated topology stays valid: full population coverage,
        // region constraints, nmax never exceeded.
        let total: usize = e.topo.edges.iter().map(|x| x.members.len()).sum();
        assert_eq!(total, n);
        for edge in &e.topo.edges {
            assert!(edge.members.len() <= cfg.topology.nmax);
            for &d in &edge.members {
                assert_eq!(e.topo.device_regions[d], edge.region);
            }
        }
    }
    assert!(
        total_reclusters >= 1,
        "no recluster fired under heavy churn with threshold 0.1"
    );
    assert!(total_migrated > 0, "reclusters moved no devices");
}

#[test]
fn semi_sync_quorum_liveness_across_recluster() {
    // Regression (membership subsystem): live migration re-derives the
    // semi-sync quorums from the new membership — a shrunken edge must
    // still close its round, and cloud windows keep completing after the
    // topology moved under the running engine.
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.hfl.threshold_time = 900.0;
    cfg.sync.mode = SyncModeCfg::SemiSync;
    cfg.sync.quorum = 2;
    cfg.sync.cloud_interval = 120.0;
    cfg.sim.leave_prob = 0.25;
    cfg.sim.join_prob = 0.5;
    cfg.cluster.recluster_threshold = 0.1;
    cfg.cluster.recluster_min_interval = 0.0;
    let mut e = AsyncHflEngine::new(cfg, false).unwrap();
    let hist = e.run_to_threshold().unwrap();
    assert!(!hist.rounds.is_empty(), "no cloud windows at all");
    let reclusters: usize = hist.rounds.iter().map(|r| r.n_reclusters).sum();
    let migrated: usize = hist.rounds.iter().map(|r| r.migrated_devices).sum();
    assert!(reclusters >= 1, "no recluster in churned semi-sync run");
    assert!(migrated > 0, "live migration moved no devices");
    // Quorum liveness across the recluster: edge rounds keep closing in
    // the windows at/after the first re-clustering.
    let first = hist
        .rounds
        .iter()
        .position(|r| r.n_reclusters > 0)
        .unwrap();
    let aggs_after: usize = hist.rounds[first..]
        .iter()
        .map(|r| r.gamma2.iter().sum::<usize>())
        .sum();
    assert!(aggs_after > 0, "no edge round closed after the recluster");
    // Warm-start downlinks actually landed and were applied.
    assert!(
        !e.migration_log.is_empty(),
        "no migration warm-start landed"
    );
}

#[test]
fn recluster_enabled_is_noop_without_churn() {
    // Bit-for-bit acceptance: enabling the membership subsystem must not
    // change a churn-free run in any way — it draws from no RNG stream
    // until it actually fires, and it can only fire after observed flips.
    require_artifacts!();
    let base = small_cfg();
    let mut enabled = base.clone();
    enabled.cluster.recluster_threshold = 0.05;
    enabled.cluster.recluster_min_interval = 0.0;
    let run = |cfg: &ExperimentConfig| {
        let mut e = HflEngine::new(cfg.clone(), true).unwrap();
        let m = e.edges();
        let mut rounds = Vec::new();
        for _ in 0..3 {
            rounds.push(e.run_round(&vec![2; m], &vec![1; m], None).unwrap());
        }
        (rounds, e.cloud_model().to_vec())
    };
    let (a, wa) = run(&base);
    let (b, wb) = run(&enabled);
    assert_eq!(wa, wb, "cloud models diverged");
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.accuracy, rb.accuracy);
        assert_eq!(ra.energy, rb.energy);
        assert_eq!(ra.round_time, rb.round_time);
        assert_eq!(ra.sim_now, rb.sim_now);
        assert_eq!(ra.n_reclusters, 0);
        assert_eq!(rb.n_reclusters, 0);
        assert_eq!(rb.migrated_devices, 0);
    }
    // Same no-op guarantee in an event-driven mode.
    let mut acfg = base.clone();
    acfg.hfl.threshold_time = 400.0;
    acfg.sync.mode = SyncModeCfg::SemiSync;
    acfg.sync.cloud_interval = 120.0;
    let mut aena = acfg.clone();
    aena.cluster.recluster_threshold = 0.05;
    aena.cluster.recluster_min_interval = 0.0;
    let run_async = |cfg: &ExperimentConfig| {
        let mut e = AsyncHflEngine::new(cfg.clone(), false).unwrap();
        let hist = e.run_to_threshold().unwrap();
        (e.transfer_log.clone(), hist)
    };
    let (la, ha) = run_async(&acfg);
    let (lb, hb) = run_async(&aena);
    assert_eq!(la, lb, "transfer timeline diverged");
    assert_eq!(ha.rounds.len(), hb.rounds.len());
    for (ra, rb) in ha.rounds.iter().zip(&hb.rounds) {
        assert_eq!(ra.accuracy, rb.accuracy);
        assert_eq!(ra.energy, rb.energy);
        assert_eq!(ra.round_time, rb.round_time);
    }
}

#[test]
fn recluster_runs_are_seed_deterministic() {
    // The whole migration pipeline — drift trigger, re-profiling,
    // clustering, warm-start downlinks — replays identically from the
    // experiment seed.
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.hfl.threshold_time = 600.0;
    cfg.sync.mode = SyncModeCfg::SemiSync;
    cfg.sync.quorum = 1;
    cfg.sync.cloud_interval = 100.0;
    cfg.sim.leave_prob = 0.25;
    cfg.sim.join_prob = 0.5;
    cfg.cluster.recluster_threshold = 0.1;
    cfg.cluster.recluster_min_interval = 0.0;
    let run = |cfg: &ExperimentConfig| {
        let mut e = AsyncHflEngine::new(cfg.clone(), false).unwrap();
        let hist = e.run_to_threshold().unwrap();
        (e.migration_log.clone(), e.transfer_log.clone(), hist)
    };
    let (ma, ta, ha) = run(&cfg);
    let (mb, tb, hb) = run(&cfg);
    assert_eq!(ma, mb, "migration landings diverged");
    assert_eq!(ta, tb, "transfer timeline diverged");
    assert_eq!(ha.rounds.len(), hb.rounds.len());
    for (ra, rb) in ha.rounds.iter().zip(&hb.rounds) {
        assert_eq!(ra.accuracy, rb.accuracy);
        assert_eq!(ra.energy, rb.energy);
        assert_eq!(ra.n_reclusters, rb.n_reclusters);
        assert_eq!(ra.migrated_devices, rb.migrated_devices);
    }
}

#[test]
fn observer_attach_is_bitwise_noop() {
    // The fourth determinism guarantee (observability subsystem): an
    // instrumented run is bitwise identical to an uninstrumented one —
    // hooks read, never mutate, and wall-clock flows only into observer
    // records. Churn-heavy semi-sync with contention and re-clustering
    // exercises every hook site (events, transfers, recluster, rounds,
    // store snapshots).
    require_artifacts!();
    let mut cfg = small_cfg();
    cfg.hfl.threshold_time = 500.0;
    cfg.sync.mode = SyncModeCfg::SemiSync;
    cfg.sync.quorum = 1;
    cfg.sync.cloud_interval = 100.0;
    cfg.link.contention = true;
    cfg.sim.leave_prob = 0.25;
    cfg.sim.join_prob = 0.5;
    cfg.cluster.recluster_threshold = 0.1;
    cfg.cluster.recluster_min_interval = 0.0;
    let run = |obs: Option<Box<dyn arena::obs::Observer>>| {
        let mut e = AsyncHflEngine::new(cfg.clone(), false).unwrap();
        if let Some(o) = obs {
            e.attach_observer(o);
        }
        let hist = e.run_to_threshold().unwrap();
        (
            e.transfer_log.clone(),
            e.migration_log.clone(),
            hist,
            e.eng.cloud_model().to_vec(),
        )
    };
    let (t_off, m_off, h_off, w_off) = run(None);
    let observer = arena::obs::RunObserver::new();
    let state = observer.state();
    let (t_on, m_on, h_on, w_on) = run(Some(Box::new(observer)));
    assert_eq!(t_off, t_on, "observer perturbed the transfer timeline");
    assert_eq!(m_off, m_on, "observer perturbed migration landings");
    assert_eq!(w_off, w_on, "observer perturbed the final model");
    // The histories export byte-for-byte identical CSVs (including the
    // schema_version header line and every per-edge column).
    let dir = std::env::temp_dir().join("arena_obs_noop");
    std::fs::create_dir_all(&dir).unwrap();
    let p_off = dir.join("off.csv");
    let p_on = dir.join("on.csv");
    h_off
        .write_csv(p_off.to_str().unwrap(), "semi-sync")
        .unwrap();
    h_on.write_csv(p_on.to_str().unwrap(), "semi-sync").unwrap();
    let b_off = std::fs::read(&p_off).unwrap();
    let b_on = std::fs::read(&p_on).unwrap();
    assert!(!b_off.is_empty(), "empty history CSV");
    assert_eq!(b_off, b_on, "history CSVs differ observer-on vs -off");
    std::fs::remove_dir_all(dir).ok();
    // Not vacuous: the attached observer actually saw the run.
    let st = state.lock().unwrap();
    assert!(st.registry.counter("arena_events_total") > 0);
    assert!(st.registry.counter("arena_transfers_total") > 0);
    assert_eq!(
        st.registry.counter("arena_rounds_total"),
        h_on.rounds.len() as u64
    );
    assert!(!st.trace.is_empty(), "no spans recorded");
}

#[test]
fn sim_workers_and_backend_are_bitwise_invisible_in_sync_equivalence() {
    // The parallel simulation layer's core contract: any `sim.workers`
    // and either queue backend reproduce the serial trajectory exactly
    // — exercised here on the sync-equivalence surface (barrier vs
    // event engine, zero churn), at workers ∈ {1, 2, 8}.
    require_artifacts!();
    let run = |workers: usize, backend: QueueBackend| {
        let mut cfg = small_cfg();
        cfg.sim.workers = workers;
        cfg.sim.queue_backend = backend;
        let mut barrier = HflEngine::new(cfg.clone(), false).unwrap();
        let mut events = AsyncHflEngine::new(cfg, false).unwrap();
        let m = barrier.edges();
        let g1 = vec![2; m];
        let g2 = vec![2; m];
        let mut rows = Vec::new();
        for _ in 0..2 {
            let a = barrier.run_round(&g1, &g2, None).unwrap();
            let b = events.run_round(&g1, &g2, None).unwrap();
            assert_eq!(a.accuracy, b.accuracy);
            assert_eq!(a.round_time, b.round_time);
            assert_eq!(a.energy, b.energy);
            rows.push((a.accuracy, a.round_time, a.energy, a.sim_now));
        }
        (rows, barrier.cloud_model().to_vec())
    };
    let reference = run(1, QueueBackend::Binary);
    for workers in [2usize, 8] {
        assert_eq!(
            run(workers, QueueBackend::Binary),
            reference,
            "trajectory changed at sim.workers={workers}"
        );
    }
    assert_eq!(
        run(8, QueueBackend::Calendar),
        reference,
        "trajectory changed under the calendar backend"
    );
}

#[test]
fn history_csvs_byte_equal_across_sim_workers_under_churn() {
    // A churn-heavy semi-sync run's exported history CSV must be
    // byte-identical at sim.workers ∈ {1, 2, 8}, under either queue
    // backend, with or without an observer attached — the bitwise
    // surface CI's multithread-determinism job diffs.
    require_artifacts!();
    let csv = |workers: usize, backend: QueueBackend, observe: bool| {
        let mut cfg = small_cfg();
        cfg.hfl.threshold_time = 500.0;
        cfg.sync.mode = SyncModeCfg::SemiSync;
        cfg.sync.quorum = 1;
        cfg.sync.cloud_interval = 100.0;
        cfg.link.contention = true;
        cfg.sim.leave_prob = 0.25;
        cfg.sim.join_prob = 0.5;
        cfg.sim.workers = workers;
        cfg.sim.queue_backend = backend;
        let mut e = AsyncHflEngine::new(cfg, false).unwrap();
        if observe {
            e.attach_observer(Box::new(arena::obs::RunObserver::new()));
        }
        let hist = e.run_to_threshold().unwrap();
        let path = std::env::temp_dir().join(format!(
            "arena_w{workers}_{}_{observe}.csv",
            backend.name()
        ));
        hist.write_csv(path.to_str().unwrap(), "semi-sync").unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        bytes
    };
    let reference = csv(1, QueueBackend::Auto, false);
    assert!(!reference.is_empty(), "empty history CSV");
    for workers in [2usize, 8] {
        assert_eq!(
            csv(workers, QueueBackend::Auto, false),
            reference,
            "history CSV changed at sim.workers={workers}"
        );
    }
    assert_eq!(
        csv(8, QueueBackend::Calendar, false),
        reference,
        "history CSV changed under the calendar backend"
    );
    assert_eq!(
        csv(8, QueueBackend::Auto, true),
        reference,
        "history CSV changed with an observer at sim.workers=8"
    );
}

#[test]
fn rearm_noop_holds_at_any_sim_workers() {
    // The fixed-knob re-arm no-op guarantee, re-run on the parallel
    // simulation path: stepping window-by-window and re-arming the
    // in-force knobs reproduces the single-call run bit-for-bit at
    // sim.workers ∈ {2, 8} too.
    require_artifacts!();
    for workers in [2usize, 8] {
        let mut cfg = small_cfg();
        cfg.hfl.threshold_time = 400.0;
        cfg.sync.mode = SyncModeCfg::SemiSync;
        cfg.sync.cloud_interval = 120.0;
        cfg.sim.workers = workers;
        let m = cfg.topology.edges;
        let g1 = vec![2usize; m];
        let alpha = vec![cfg.sync.staleness_alpha; m];

        let mut plain = AsyncHflEngine::new(cfg.clone(), false).unwrap();
        let hist_a = plain.run_with(&g1).unwrap();

        let mut stepped = AsyncHflEngine::new(cfg, false).unwrap();
        stepped.begin_run(&g1).unwrap();
        let mut windows = 0usize;
        while stepped.run_window().unwrap().is_some() {
            windows += 1;
            stepped.set_control(&g1, &alpha).unwrap();
        }
        assert_eq!(
            plain.transfer_log, stepped.transfer_log,
            "workers={workers}: transfer timeline diverged"
        );
        assert_eq!(hist_a.rounds.len(), windows, "workers={workers}");
        assert_eq!(
            plain.eng.cloud_model(),
            stepped.eng.cloud_model(),
            "workers={workers}: models diverged"
        );
    }
}

#[test]
fn fault_storm_worker_sweep_is_bitwise_identical() {
    // The sharded engine loop's full-stack stress matrix: a churn-heavy
    // semi-sync run with over-selection, a seeded fault storm (outages +
    // a partition + a crash storm), and a *learned* controller that
    // re-arms changed knobs at every window boundary, swept over
    // sim.workers ∈ {1, 2, 8} × queue backend {binary, calendar} ×
    // profiler on/off. Every cell must reproduce the reference cell's
    // transfer timeline, migration landings, history CSV bytes, and
    // final cloud model exactly — faults, migrations, and mid-run
    // control changes all cross shard barriers, so this pins the
    // action-replay merge order end to end.
    require_artifacts!();
    let base_alpha = small_cfg().sync.staleness_alpha;
    let run = |workers: usize, backend: QueueBackend, profiled: bool| {
        let mut cfg = small_cfg();
        cfg.hfl.threshold_time = 700.0;
        cfg.sync.mode = SyncModeCfg::SemiSync;
        cfg.sync.quorum = 1;
        cfg.sync.cloud_interval = 100.0;
        cfg.link.contention = true;
        cfg.sim.leave_prob = 0.25;
        cfg.sim.join_prob = 0.5;
        cfg.cluster.recluster_threshold = 0.1;
        cfg.cluster.recluster_min_interval = 0.0;
        cfg.lifecycle.overselect = 1.5;
        cfg.fault.outages = 2;
        cfg.fault.outage_duration = 80.0;
        cfg.fault.partitions = 1;
        cfg.fault.partition_duration = 120.0;
        cfg.fault.crash_storms = 1;
        cfg.fault.crash_frac = 0.4;
        cfg.fault.rejoin_delay = 60.0;
        cfg.sim.workers = workers;
        cfg.sim.queue_backend = backend;
        cfg.sim.profiler = profiled;
        let m = cfg.topology.edges;
        let mut e = AsyncHflEngine::new(cfg, false).unwrap();
        if profiled {
            e.attach_observer(Box::new(arena::obs::RunObserver::new()));
        }
        // Window-varying control schedule, identical in every cell: the
        // "learned" knobs change at each barrier, so re-arming is NOT a
        // no-op here — the sweep checks that knob changes land at the
        // same window boundary regardless of worker count.
        e.begin_run(&vec![2; m]).unwrap();
        let mut hist = arena::hfl::RunHistory::default();
        let mut w = 0usize;
        while let Some(stats) = e.run_window().unwrap() {
            hist.push(stats);
            w += 1;
            let g1: Vec<usize> = (0..m).map(|j| 1 + (w + j) % 3).collect();
            let alpha: Vec<f64> = (0..m)
                .map(|j| base_alpha * (1.0 + 0.25 * ((w + j) % 2) as f64))
                .collect();
            e.set_control(&g1, &alpha).unwrap();
        }
        let path = std::env::temp_dir().join(format!(
            "arena_storm_w{workers}_{}_{profiled}.csv",
            backend.name()
        ));
        hist.write_csv(path.to_str().unwrap(), "storm").unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        (
            e.transfer_log.clone(),
            e.migration_log.clone(),
            bytes,
            e.eng.cloud_model().to_vec(),
            hist.rounds.iter().map(|r| r.fault_events).sum::<usize>(),
        )
    };
    let reference = run(1, QueueBackend::Binary, false);
    assert!(!reference.2.is_empty(), "empty history CSV");
    assert!(
        reference.4 > 0,
        "vacuous storm: no fault events reached the history"
    );
    for workers in [1usize, 2, 8] {
        for backend in [QueueBackend::Binary, QueueBackend::Calendar] {
            for profiled in [false, true] {
                assert_eq!(
                    run(workers, backend, profiled),
                    reference,
                    "trajectory diverged at workers={workers} \
                     backend={} profiler={profiled}",
                    backend.name()
                );
            }
        }
    }
}

#[test]
fn pca_scores_via_artifact_match_cpu() {
    require_artifacts!();
    let cfg = small_cfg();
    let mut engine = HflEngine::new(cfg, false).unwrap();
    let m = engine.edges();
    engine.run_round(&vec![1; m], &vec![1; m], None).unwrap();
    let stack = engine.model_stack();
    let pca = arena::pca::PcaModel::fit(&stack, 6);
    let via_artifact = engine.pca_scores(&pca).unwrap();
    let stack = engine.model_stack();
    let via_cpu = pca.transform_cpu(&stack);
    for (a, c) in via_artifact.iter().zip(&via_cpu) {
        for (x, y) in a.iter().zip(c) {
            let tol = 1e-2f32.max(y.abs() * 1e-3);
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }
}
