//! Parallel-runtime profiler guarantees (artifact-free).
//!
//! The fifth bitwise guarantee: a profiled sharded run (observer
//! attached, `ShardProfiler` recording on the hot path) produces
//! byte-identical trajectory CSVs to an unprofiled one at every worker
//! count and queue backend. On top of that, the merged exposition must
//! keep one stable metric key set across worker counts, and every
//! sim-derived series (event counts, queue depths, imbalance, store
//! observables) must be byte-identical — only wall-clock series
//! (`*_wall_ns`, stalls, busy, occupancy) and the worker-count gauges
//! may differ between runs.

use arena::obs::RunObserver;
use arena::sim::{QueueBackend, ShardSpec, ShardedDeviceSim};

/// Small but churny sharded topology: joins/leaves every window, a few
/// devices per shard, cross-shard traffic at every barrier.
fn churny_spec(workers: usize, backend: QueueBackend) -> ShardSpec {
    ShardSpec {
        devices: 96,
        edges: 8,
        shards: 4,
        p: 16,
        windows: 4,
        leave_prob: 0.1,
        join_prob: 0.4,
        workers,
        backend,
        ..Default::default()
    }
}

/// Metric families whose values are pure functions of the simulated
/// trajectory — byte-identical at any worker count. Everything else in
/// the shard/pool families carries wall-clock or the worker count.
const SIM_DERIVED: &[&str] = &[
    "arena_shard_windows_total",
    "arena_shard_events_total",
    "arena_shard_voided_total",
    "arena_shard_aggregates_total",
    "arena_shard_flips_total",
    "arena_shard_adopt_across_total",
    "arena_shard_replicate_total",
    "arena_shard_count",
    "arena_shard_live_devices",
    "arena_shard_queue_depth_peak",
    "arena_shard_imbalance",
    "arena_sharded_store_live_buffers",
    "arena_sharded_store_peak_bytes",
    "arena_sharded_store_sharing_ratio",
    "arena_shard_events_per_window",
    "arena_shard_queue_depth",
];

/// Base metric name of an exposition line (`# TYPE` comment, plain
/// sample, labeled sample or histogram series line).
fn base_name(line: &str) -> Option<&str> {
    if let Some(rest) = line.strip_prefix("# TYPE ") {
        return rest.split_whitespace().next();
    }
    let tok = line.split_whitespace().next()?;
    tok.split('{').next()
}

/// Membership check runs BEFORE suffix stripping so gauge names that
/// happen to end in a histogram suffix (`arena_shard_count`) are not
/// mangled into a different family.
fn is_sim_derived(name: &str) -> bool {
    if SIM_DERIVED.contains(&name) {
        return true;
    }
    ["_bucket", "_sum", "_count"].iter().any(|suf| {
        name.strip_suffix(suf)
            .is_some_and(|b| SIM_DERIVED.contains(&b))
    })
}

/// Run a profiled sharded sim and return (trajectory CSV, exposition).
fn profiled_run(workers: usize, backend: QueueBackend) -> (String, String) {
    let obs = RunObserver::new();
    let state = obs.state();
    let mut sim = ShardedDeviceSim::new(&churny_spec(workers, backend));
    sim.attach_observer(Box::new(obs));
    sim.run();
    let exposition = state.lock().unwrap().registry.render_prometheus();
    (sim.csv_string(), exposition)
}

#[test]
fn profiler_is_bitwise_invisible_across_workers_and_backends() {
    // Reference: serial, unprofiled, binary heap.
    let mut sim =
        ShardedDeviceSim::new(&churny_spec(1, QueueBackend::Binary));
    sim.set_profiler(false);
    sim.run();
    let reference = sim.csv_string();
    assert!(reference.contains('\n'), "reference run produced no rows");

    for backend in [QueueBackend::Binary, QueueBackend::Calendar] {
        for workers in [1usize, 2, 8] {
            let (profiled, _) = profiled_run(workers, backend);
            assert_eq!(
                profiled, reference,
                "profiled run diverged at workers={workers} {backend:?}"
            );
            let mut bare =
                ShardedDeviceSim::new(&churny_spec(workers, backend));
            bare.set_profiler(false);
            bare.run();
            assert_eq!(
                bare.csv_string(),
                reference,
                "unprofiled run diverged at workers={workers} {backend:?}"
            );
        }
    }
}

#[test]
fn exposition_structure_is_stable_across_worker_counts() {
    let runs: Vec<(usize, String)> = [1usize, 2, 8]
        .iter()
        .map(|&w| (w, profiled_run(w, QueueBackend::Auto).1))
        .collect();

    // Same metric key set everywhere (the `# TYPE` lines name every
    // exported family exactly once).
    let key_set = |text: &str| -> Vec<String> {
        text.lines()
            .filter(|l| l.starts_with("# TYPE "))
            .filter_map(base_name)
            .map(str::to_string)
            .collect()
    };
    let reference_keys = key_set(&runs[0].1);
    assert!(
        reference_keys.iter().any(|k| k == "arena_shard_events_total"),
        "shard metrics missing from exposition: {reference_keys:?}"
    );
    assert!(
        reference_keys.iter().any(|k| k == "arena_pool_occupancy"),
        "pool metrics missing from exposition: {reference_keys:?}"
    );
    for (w, text) in &runs[1..] {
        assert_eq!(
            key_set(text),
            reference_keys,
            "metric key set changed at workers={w}"
        );
    }

    // Sim-derived series — values included — are byte-identical.
    let sim_lines = |text: &str| -> String {
        text.lines()
            .filter(|l| base_name(l).is_some_and(is_sim_derived))
            .collect::<Vec<_>>()
            .join("\n")
    };
    let reference_lines = sim_lines(&runs[0].1);
    assert!(
        reference_lines.contains("arena_shard_imbalance"),
        "sim-derived filter matched nothing"
    );
    for (w, text) in &runs[1..] {
        assert_eq!(
            sim_lines(text),
            reference_lines,
            "sim-derived metric values changed at workers={w}"
        );
    }
}

#[test]
fn profiled_run_reports_consistent_shard_metrics() {
    let obs = RunObserver::new();
    let state = obs.state();
    let spec = churny_spec(2, QueueBackend::Auto);
    let mut sim = ShardedDeviceSim::new(&spec);
    sim.attach_observer(Box::new(obs));
    sim.run();

    let st = state.lock().unwrap();
    let r = &st.registry;
    assert_eq!(
        r.counter("arena_shard_windows_total"),
        spec.windows as u64
    );
    assert_eq!(r.counter("arena_shard_events_total"), sim.stats().events);
    assert_eq!(
        r.counter("arena_shard_aggregates_total"),
        sim.stats().aggregates
    );
    assert_eq!(r.gauge("arena_pool_workers"), Some(2.0));
    assert_eq!(r.gauge("arena_shard_count"), Some(4.0));
    // One advance-wall sample per shard per window.
    let h = r.histogram("arena_shard_advance_wall_ns").unwrap();
    assert_eq!(h.count(), (spec.windows * 4) as u64);
    let stalls = r.histogram("arena_shard_barrier_stall_ns").unwrap();
    assert_eq!(stalls.count(), (spec.windows * 4) as u64);
    // Shard and worker tracks landed in the trace.
    let tracks = st.trace.tracks();
    assert!(tracks.iter().any(|t| t == "shard/0"), "{tracks:?}");
    assert!(
        tracks.iter().any(|t| t.starts_with("worker/")),
        "{tracks:?}"
    );
}

#[test]
fn profiler_toggle_controls_shard_metrics() {
    let obs = RunObserver::new();
    let state = obs.state();
    let mut sim =
        ShardedDeviceSim::new(&churny_spec(2, QueueBackend::Auto));
    sim.set_profiler(false);
    sim.attach_observer(Box::new(obs));
    sim.run();
    let st = state.lock().unwrap();
    assert_eq!(st.registry.counter("arena_shard_windows_total"), 0);
    assert!(st.trace.is_empty(), "profiler off must add no spans");
}
