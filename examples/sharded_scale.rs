//! Sharded parallel event engine at scale — no artifacts needed.
//!
//! Runs the device-sharded discrete-event simulation
//! (`sim::shard::ShardedDeviceSim`): devices partitioned by edge into
//! shards, each shard owning its own event heap, RNG streams and model
//! slab, advanced by a persistent worker pool up to a conservative
//! time-window barrier and merged in fixed shard order. The merged
//! trajectory — every history row, every checksum — is bitwise identical
//! for ANY worker count and either queue backend; only the wall-clock
//! changes. This is also the churn-heavy workload CI diffs across
//! worker counts.
//!
//! `cargo run --release --example sharded_scale -- \
//!     --devices 1000000 --edges 64 --windows 3 --workers 8 \
//!     --backend auto --csv /tmp/sharded.csv --profile`
//!
//! `--profile` attaches the read-only `RunObserver` with the per-shard
//! profiler on and prints barrier-stall percentiles, the shard
//! imbalance and worker occupancy after the run — without changing a
//! single output bit (the fifth determinism guarantee, tested).
//!
//! Fault injection (`--outages N --outage-duration S --partitions N
//! --partition-duration S --crash-storms N --crash-frac F
//! --rejoin-delay S`) expands a seeded `FaultPlan` into scheduled
//! events: edge outages void stragglers, partitions sever edge→cloud
//! uploads, crash storms kill a deterministic device subset and rejoin
//! it later. The injected trajectory — faults column included — stays
//! bitwise identical at any worker count; the CI chaos job diffs
//! exactly this.

use anyhow::{bail, Result};
use arena::obs::RunObserver;
use arena::sim::{QueueBackend, ShardSpec, ShardedDeviceSim};

fn main() -> Result<()> {
    let mut spec = ShardSpec {
        devices: 200_000,
        edges: 64,
        windows: 4,
        ..ShardSpec::default()
    };
    let mut csv: Option<String> = None;
    let mut profile = false;

    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        let need = |i: usize| -> Result<&String> {
            argv.get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("{} needs a value", argv[i]))
        };
        // Valueless switches first; everything below takes a value.
        if argv[i] == "--profile" {
            profile = true;
            i += 1;
            continue;
        }
        match argv[i].as_str() {
            "--devices" => spec.devices = need(i)?.parse()?,
            "--edges" => spec.edges = need(i)?.parse()?,
            "--shards" => spec.shards = need(i)?.parse()?,
            "--windows" => spec.windows = need(i)?.parse()?,
            "--workers" => spec.workers = need(i)?.parse()?,
            "--seed" => spec.seed = need(i)?.parse()?,
            "--leave-prob" => spec.leave_prob = need(i)?.parse()?,
            "--join-prob" => spec.join_prob = need(i)?.parse()?,
            "--backend" => spec.backend = QueueBackend::parse(need(i)?)?,
            "--outages" => spec.outages = need(i)?.parse()?,
            "--outage-duration" => spec.outage_duration = need(i)?.parse()?,
            "--partitions" => spec.partitions = need(i)?.parse()?,
            "--partition-duration" => {
                spec.partition_duration = need(i)?.parse()?
            }
            "--crash-storms" => spec.crash_storms = need(i)?.parse()?,
            "--crash-frac" => spec.crash_frac = need(i)?.parse()?,
            "--rejoin-delay" => spec.rejoin_delay = need(i)?.parse()?,
            "--csv" => csv = Some(need(i)?.clone()),
            other => bail!("unknown flag {other} (see module doc)"),
        }
        i += 2;
    }

    println!(
        "sharded sim: {} devices / {} edges / {} shards, {} windows, \
         workers={} ({}), backend={}",
        spec.devices,
        spec.edges,
        spec.resolved_shards(),
        spec.windows,
        spec.workers,
        spec.resolved_workers(),
        spec.backend.name(),
    );

    let t0 = std::time::Instant::now();
    let mut sim = ShardedDeviceSim::new(&spec);
    let built = t0.elapsed();
    let obs_state = if profile {
        let obs = RunObserver::new();
        let state = obs.state();
        sim.attach_observer(Box::new(obs));
        Some(state)
    } else {
        None
    };
    let t1 = std::time::Instant::now();
    sim.run();
    let ran = t1.elapsed();

    for row in sim.history() {
        println!(
            "window {:>3}  t={:>9.1}s  events={:>9}  live={:>8}  \
             loss={:.4}  aggs={:>6}  checksum={:016x}",
            row.window,
            row.sim_time,
            row.events,
            row.live,
            row.loss,
            row.aggregates,
            row.checksum,
        );
    }
    let st = sim.stats();
    println!(
        "totals: {} events ({} voided), {} aggregates, {} flips, \
         peak shard queue {}, live buffers {}",
        st.events,
        st.voided,
        st.aggregates,
        st.flips,
        st.peak_queue_len,
        st.store_live,
    );
    if spec.outages > 0 || spec.partitions > 0 || spec.crash_storms > 0 {
        println!(
            "faults: {} outage downs, {} severed edges, {} crashed \
             devices (seeded plan — identical at any worker count)",
            st.outages,
            st.partitions,
            st.crashes,
        );
    }
    let evs = st.events as f64 / ran.as_secs_f64().max(1e-9);
    println!(
        "built in {:.2}s, ran in {:.2}s ({:.0} events/s)",
        built.as_secs_f64(),
        ran.as_secs_f64(),
        evs,
    );

    if let Some(state) = obs_state {
        let st = state.lock().unwrap();
        let r = &st.registry;
        if let Some(h) = r.histogram("arena_shard_barrier_stall_ns") {
            println!(
                "profile: barrier stall p50={:.0}ns p99={:.0}ns \
                 (n={})",
                h.percentile(50.0),
                h.percentile(99.0),
                h.count(),
            );
        }
        println!(
            "profile: imbalance={:.3} (max/mean events), \
             occupancy={:.3} @ {} workers",
            r.gauge("arena_shard_imbalance").unwrap_or(1.0),
            r.gauge("arena_pool_occupancy").unwrap_or(0.0),
            r.gauge("arena_pool_workers").unwrap_or(0.0),
        );
    }

    if let Some(path) = csv {
        sim.write_csv(&path)?;
        println!("history written to {path}");
    }
    Ok(())
}
