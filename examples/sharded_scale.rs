//! Sharded parallel event engine at scale — no artifacts needed.
//!
//! Runs the device-sharded discrete-event simulation
//! (`sim::shard::ShardedDeviceSim`): devices partitioned by edge into
//! shards, each shard owning its own event heap, RNG streams and model
//! slab, advanced by a persistent worker pool up to a conservative
//! time-window barrier and merged in fixed shard order. The merged
//! trajectory — every history row, every checksum — is bitwise identical
//! for ANY worker count and either queue backend; only the wall-clock
//! changes. This is also the churn-heavy workload CI diffs across
//! worker counts.
//!
//! `cargo run --release --example sharded_scale -- \
//!     --devices 1000000 --edges 64 --windows 3 --workers 8 \
//!     --backend auto --csv /tmp/sharded.csv`

use anyhow::{bail, Result};
use arena::sim::{QueueBackend, ShardSpec, ShardedDeviceSim};

fn main() -> Result<()> {
    let mut spec = ShardSpec {
        devices: 200_000,
        edges: 64,
        windows: 4,
        ..ShardSpec::default()
    };
    let mut csv: Option<String> = None;

    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        let need = |i: usize| -> Result<&String> {
            argv.get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("{} needs a value", argv[i]))
        };
        match argv[i].as_str() {
            "--devices" => spec.devices = need(i)?.parse()?,
            "--edges" => spec.edges = need(i)?.parse()?,
            "--shards" => spec.shards = need(i)?.parse()?,
            "--windows" => spec.windows = need(i)?.parse()?,
            "--workers" => spec.workers = need(i)?.parse()?,
            "--seed" => spec.seed = need(i)?.parse()?,
            "--leave-prob" => spec.leave_prob = need(i)?.parse()?,
            "--join-prob" => spec.join_prob = need(i)?.parse()?,
            "--backend" => spec.backend = QueueBackend::parse(need(i)?)?,
            "--csv" => csv = Some(need(i)?.clone()),
            other => bail!("unknown flag {other} (see module doc)"),
        }
        i += 2;
    }

    println!(
        "sharded sim: {} devices / {} edges / {} shards, {} windows, \
         workers={} ({}), backend={}",
        spec.devices,
        spec.edges,
        spec.resolved_shards(),
        spec.windows,
        spec.workers,
        spec.resolved_workers(),
        spec.backend.name(),
    );

    let t0 = std::time::Instant::now();
    let mut sim = ShardedDeviceSim::new(&spec);
    let built = t0.elapsed();
    let t1 = std::time::Instant::now();
    sim.run();
    let ran = t1.elapsed();

    for row in sim.history() {
        println!(
            "window {:>3}  t={:>9.1}s  events={:>9}  live={:>8}  \
             loss={:.4}  aggs={:>6}  checksum={:016x}",
            row.window,
            row.sim_time,
            row.events,
            row.live,
            row.loss,
            row.aggregates,
            row.checksum,
        );
    }
    let st = sim.stats();
    println!(
        "totals: {} events ({} voided), {} aggregates, {} flips, \
         peak shard queue {}, live buffers {}",
        st.events,
        st.voided,
        st.aggregates,
        st.flips,
        st.peak_queue_len,
        st.store_live,
    );
    let evs = st.events as f64 / ran.as_secs_f64().max(1e-9);
    println!(
        "built in {:.2}s, ran in {:.2}s ({:.0} events/s)",
        built.as_secs_f64(),
        ran.as_secs_f64(),
        evs,
    );

    if let Some(path) = csv {
        sim.write_csv(&path)?;
        println!("history written to {path}");
    }
    Ok(())
}
