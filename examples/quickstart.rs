//! Quickstart: a few fixed-frequency HFL rounds on the MNIST-shape
//! workload. Run with `cargo run --release --example quickstart`
//! (after `make artifacts`).

use anyhow::Result;
use arena::config::ExperimentConfig;
use arena::hfl::HflEngine;

fn main() -> Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let mut cfg = ExperimentConfig::mnist();
    cfg.topology.devices = 10; // tiny demo
    cfg.hfl.threshold_time = 600.0;
    let mut engine = HflEngine::new(cfg, true)?;
    println!(
        "arena quickstart: {} devices / {} edges on PJRT '{}'",
        engine.cfg.topology.devices,
        engine.edges(),
        engine.rt.platform()
    );
    let m = engine.edges();
    while engine.remaining_time() > 0.0 {
        let stats = engine.run_round(&vec![3; m], &vec![2; m], None)?;
        println!(
            "round {:>2}: sim t={:>7.1}s  acc={:.3}  loss={:.3}  energy={:.1} mAh",
            stats.k, stats.sim_now, stats.accuracy, stats.train_loss,
            stats.energy
        );
    }
    println!("done — the model learned from the synthetic non-IID shards.");
    Ok(())
}
