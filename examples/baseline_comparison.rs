//! Compare every non-learning scheme under an identical budget — the
//! Fig. 2-style motivation table.
//!
//! `cargo run --release --example baseline_comparison`

use anyhow::Result;
use arena::baselines;
use arena::config::ExperimentConfig;
use arena::hfl::HflEngine;

fn main() -> Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let mut cfg = ExperimentConfig::mnist();
    cfg.topology.devices = 10;
    cfg.hfl.threshold_time = 1000.0;
    println!(
        "scheme        final-acc  best-acc  energy/device  rounds"
    );
    let runs: Vec<(&str, Box<dyn Fn(&mut HflEngine) -> Result<_>>)> = vec![
        ("vanilla-fl", Box::new(|e: &mut HflEngine| {
            baselines::vanilla_fl(e, 0.6)
        })),
        ("vanilla-hfl", Box::new(baselines::vanilla_hfl)),
        ("var-freq-a", Box::new(baselines::var_freq::var_freq_a)),
        ("var-freq-b", Box::new(baselines::var_freq::var_freq_b)),
        ("share", Box::new(baselines::share::share)),
        ("favor", Box::new(|e: &mut HflEngine| {
            baselines::favor::favor(
                e,
                &baselines::favor::FavorOptions::default(),
            )
        })),
    ];
    for (name, f) in runs {
        let profiled = matches!(name, "var-freq-a" | "var-freq-b" | "share");
        let mut engine = HflEngine::new(cfg.clone(), profiled)?;
        let h = f(&mut engine)?;
        println!(
            "{name:<13} {:.3}      {:.3}     {:>8.1} mAh   {}",
            h.final_accuracy(),
            h.best_accuracy(),
            h.total_energy() / cfg.topology.devices as f64,
            h.rounds.len()
        );
    }
    Ok(())
}
