//! End-to-end driver (the EXPERIMENTS.md §E2E run): train the Arena PPO
//! agent on the MNIST-shape HFL workload, then roll out the learned
//! synchronization policy and compare it against Vanilla-HFL under the
//! same budget. Exercises every layer: Pallas kernels inside the AOT
//! artifacts, the PJRT runtime, the HFL engine, the profiling module and
//! the DRL loop.
//!
//! `cargo run --release --example train_arena [-- episodes]`

use anyhow::Result;
use arena::agent::{train_arena, ArenaOptions};
use arena::baselines;
use arena::config::ExperimentConfig;
use arena::hfl::HflEngine;

fn main() -> Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let episodes: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(8);
    let mut cfg = ExperimentConfig::mnist();
    cfg.topology.devices = 10;
    cfg.hfl.threshold_time = 1500.0;
    cfg.agent.episodes = episodes;

    println!("=== baseline: Vanilla-HFL ===");
    let mut engine = HflEngine::new(cfg.clone(), true)?;
    let base = baselines::vanilla_hfl(&mut engine)?;
    for r in &base.rounds {
        println!(
            "  k={:<2} t={:>7.1}s acc={:.3} loss={:.3}",
            r.k, r.sim_now, r.accuracy, r.train_loss
        );
    }

    println!("=== training Arena ({episodes} episodes) ===");
    let opts = ArenaOptions {
        verbose: true,
        ..ArenaOptions::arena(episodes)
    };
    let (agent, sb, logs) = train_arena(&mut engine, &opts)?;

    println!("=== greedy rollout of the learned policy ===");
    let hist =
        arena::agent::arena::run_arena_policy(&mut engine, &agent, &sb, true)?;
    for r in &hist.rounds {
        println!(
            "  k={:<2} t={:>7.1}s acc={:.3} g1={:?} g2={:?} E={:.1}mAh",
            r.k, r.sim_now, r.accuracy, r.gamma1, r.gamma2, r.energy
        );
    }
    let n = engine.cfg.topology.devices as f64;
    println!("---------------------------------------------");
    println!(
        "vanilla-hfl: acc {:.3}, energy/device {:>7.1} mAh",
        base.final_accuracy(),
        base.total_energy() / n
    );
    println!(
        "arena:       acc {:.3}, energy/device {:>7.1} mAh ({} episodes, final reward {:.2})",
        hist.final_accuracy(),
        hist.total_energy() / n,
        logs.len(),
        logs.last().map(|l| l.reward).unwrap_or(0.0)
    );
    Ok(())
}
