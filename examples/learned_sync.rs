//! Learned per-edge synchronization control of the event-driven engine:
//! train the DRL agent ON `AsyncHflEngine` (per-edge local-epoch counts
//! γ1_j + staleness exponents α_j, re-armed at every cloud decision
//! point), then roll the greedy policy out against the fixed-α async
//! baseline on the same seed. Exercises the `ControlledEngine` path, the
//! extended control state (staleness / in-flight / quorum-fill rows) and
//! the `_ctrl` PPO artifacts end-to-end.
//!
//! `cargo run --release --example learned_sync [-- episodes]`

use anyhow::Result;
use arena::agent::{run_policy_on, train_arena_on, ArenaOptions};
use arena::config::{ExperimentConfig, SyncModeCfg};
use arena::hfl::{AsyncHflEngine, RunHistory};
use arena::runtime::Runtime;

fn report(label: &str, hist: &RunHistory) {
    for r in &hist.rounds {
        println!(
            "  k={:<2} t={:>7.1}s acc={:.3} E={:>7.2}mAh g1={:?} \
             staleness={:.2}",
            r.k,
            r.sim_now,
            r.accuracy,
            r.energy,
            r.gamma1,
            r.mean_staleness()
        );
    }
    println!(
        "  {label}: final acc {:.3}, total energy {:.1} mAh over {:.0}s",
        hist.final_accuracy(),
        hist.total_energy(),
        hist.total_time()
    );
}

fn main() -> Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let dir = std::env::var("ARENA_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return Ok(());
    }
    // The learned controller needs the `_ctrl` agent variant (extended
    // control-state layout); older artifact sets predate it.
    let rt = Runtime::load(&dir, &[])?;
    if !rt.manifest.artifacts.contains_key("ppo_actor_fwd_ctrl") {
        eprintln!(
            "skipping: artifact set has no ppo_actor_fwd_ctrl (re-run \
             `make artifacts` to add the control-state variants)"
        );
        return Ok(());
    }
    drop(rt);

    let episodes: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let mut cfg = ExperimentConfig::mnist();
    cfg.topology.devices = 10;
    cfg.hfl.threshold_time = 600.0;
    cfg.sync.mode = SyncModeCfg::Async;
    cfg.sync.cloud_interval = 120.0;
    cfg.agent.episodes = episodes;
    cfg.artifacts_dir = dir;

    println!("=== baseline: fixed-α async (uniform γ1) ===");
    let mut engine = AsyncHflEngine::new(cfg.clone(), true)?;
    let base = engine.run_to_threshold()?;
    report("fixed-α async", &base);

    println!("=== training the per-edge (γ1_j, α_j) controller \
              ({episodes} episodes) ===");
    let mut learned_cfg = cfg.clone();
    learned_cfg.sync.learned = true;
    let mut engine = AsyncHflEngine::new(learned_cfg.clone(), true)?;
    let opts = ArenaOptions {
        verbose: true,
        ..ArenaOptions::arena(episodes)
    };
    let (agent, sb, _) = train_arena_on(&mut engine, &opts)?;

    println!("=== greedy rollout of the learned controller ===");
    // Fresh engine: training advanced the RNG/churn process on the old
    // one, and the comparison against the baseline above should be a
    // pure function of the seed.
    let mut engine = AsyncHflEngine::new(learned_cfg, true)?;
    let hist = run_policy_on(&mut engine, &agent, &sb, true)?;
    report("arena-learned", &hist);

    println!(
        "\nlearned vs fixed-α: acc {:.3} vs {:.3}, energy {:.1} vs {:.1} mAh",
        hist.final_accuracy(),
        base.final_accuracy(),
        hist.total_energy(),
        base.total_energy()
    );
    Ok(())
}
