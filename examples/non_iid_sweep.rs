//! Sweep data-heterogeneity regimes (IID / label-skew / Dirichlet) under
//! Vanilla-HFL — the Fig. 10/11 data axis in miniature.
//!
//! `cargo run --release --example non_iid_sweep`

use anyhow::Result;
use arena::baselines;
use arena::config::{ExperimentConfig, Partition};
use arena::data::partition::{mean_label_entropy, partition_labels};
use arena::hfl::HflEngine;
use arena::util::rng::Rng;

fn main() -> Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let mut base = ExperimentConfig::mnist();
    base.topology.devices = 10;
    base.hfl.threshold_time = 800.0;
    for (name, part) in [
        ("iid", Partition::Iid),
        ("label5", Partition::LabelSkew { labels: 5 }),
        ("label2", Partition::LabelSkew { labels: 2 }),
        ("dirichlet0.5", Partition::Dirichlet { alpha: 0.5 }),
    ] {
        let mut cfg = base.clone();
        cfg.hfl.partition = part;
        let mut rng = Rng::new(cfg.seed);
        let parts = partition_labels(
            part,
            cfg.topology.devices,
            cfg.hfl.samples_per_device,
            10,
            &mut rng,
        );
        let entropy = mean_label_entropy(&parts, 10);
        let mut engine = HflEngine::new(cfg.clone(), true)?;
        let h = baselines::vanilla_hfl(&mut engine)?;
        println!(
            "{name:<13} entropy {entropy:.2} bits  acc {:.3}  energy/dev {:.1} mAh",
            h.final_accuracy(),
            h.total_energy() / cfg.topology.devices as f64
        );
    }
    println!("(higher heterogeneity => lower accuracy, as in Fig. 11)");
    Ok(())
}
