//! Sharded AsyncHflEngine event loop at scale — no artifacts needed.
//!
//! Runs the engine-shard harness (`hfl::ShardedEngineLoop`): the full
//! `AsyncHflEngine` timer-mode event loop — per-edge event heaps on
//! worker threads, ctrl-queue barriers for cloud windows / churn /
//! seeded faults, semi-sync quorums with over-selection or fully-async
//! staleness bookkeeping — minus the model math (action streams fold
//! into per-window checksums instead of replaying against a model
//! store). The merged trajectory — every history row, every checksum —
//! is bitwise identical for ANY worker count and either queue backend;
//! only the wall-clock changes. This is the workload the
//! multithread-determinism CI job diffs at workers 1 vs 8 and the
//! engine-level `threads_speedup` bench times.
//!
//! `cargo run --release --example engine_scale -- \
//!     --devices 1000000 --edges 64 --windows 3 --workers 8 \
//!     --backend auto --async --csv /tmp/engine.csv`
//!
//! Churn (`--leave-prob P --join-prob P`), over-selection
//! (`--overselect F`, semi-sync only) and fault injection (`--outages N
//! --outage-duration S --partitions N --partition-duration S
//! --crash-storms N --crash-frac F --rejoin-delay S`) all ride the same
//! seeded ctrl timeline, so the injected trajectory stays bitwise
//! identical at any worker count.

use anyhow::{bail, Result};
use arena::hfl::{EngineLoopSpec, ShardedEngineLoop};
use arena::sim::QueueBackend;

fn main() -> Result<()> {
    let mut spec = EngineLoopSpec {
        devices: 200_000,
        edges: 64,
        windows: 4,
        workers: 0,
        ..EngineLoopSpec::default()
    };
    let mut csv: Option<String> = None;

    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut i = 0;
    while i < argv.len() {
        let need = |i: usize| -> Result<&String> {
            argv.get(i + 1)
                .ok_or_else(|| anyhow::anyhow!("{} needs a value", argv[i]))
        };
        // Valueless switches first; everything below takes a value.
        if argv[i] == "--async" {
            spec.asynchronous = true;
            i += 1;
            continue;
        }
        match argv[i].as_str() {
            "--devices" => spec.devices = need(i)?.parse()?,
            "--edges" => spec.edges = need(i)?.parse()?,
            "--shards" => spec.shards = need(i)?.parse()?,
            "--windows" => spec.windows = need(i)?.parse()?,
            "--workers" => spec.workers = need(i)?.parse()?,
            "--seed" => spec.seed = need(i)?.parse()?,
            "--backend" => spec.backend = QueueBackend::parse(need(i)?)?,
            "--quorum" => spec.quorum = need(i)?.parse()?,
            "--overselect" => spec.overselect = need(i)?.parse()?,
            "--alpha" => spec.staleness_alpha = need(i)?.parse()?,
            "--interval" => spec.interval = need(i)?.parse()?,
            "--epochs" => spec.epochs = need(i)?.parse()?,
            "--leave-prob" => spec.leave_prob = need(i)?.parse()?,
            "--join-prob" => spec.join_prob = need(i)?.parse()?,
            "--outages" => spec.fault.outages = need(i)?.parse()?,
            "--outage-duration" => {
                spec.fault.outage_duration = need(i)?.parse()?
            }
            "--partitions" => spec.fault.partitions = need(i)?.parse()?,
            "--partition-duration" => {
                spec.fault.partition_duration = need(i)?.parse()?
            }
            "--crash-storms" => spec.fault.crash_storms = need(i)?.parse()?,
            "--crash-frac" => spec.fault.crash_frac = need(i)?.parse()?,
            "--rejoin-delay" => spec.fault.rejoin_delay = need(i)?.parse()?,
            "--csv" => csv = Some(need(i)?.clone()),
            other => bail!("unknown flag {other} (see module doc)"),
        }
        i += 2;
    }

    println!(
        "engine loop: {} devices / {} edges / {} shards, {} windows, \
         mode={}, workers={} ({}), backend={}",
        spec.devices,
        spec.edges,
        spec.resolved_shards(),
        spec.windows,
        if spec.asynchronous { "async" } else { "semi-sync" },
        spec.workers,
        spec.resolved_workers(),
        spec.backend.name(),
    );

    let t0 = std::time::Instant::now();
    let mut sim = ShardedEngineLoop::new(&spec);
    let built = t0.elapsed();
    let t1 = std::time::Instant::now();
    sim.run();
    let ran = t1.elapsed();

    for row in sim.history() {
        println!(
            "window {:>3}  t={:>9.1}s  events={:>9}  landings={:>6}  \
             aggs={:>6}  flips={:>6}  faults={:>3}  checksum={:016x}",
            row.window,
            row.sim_time,
            row.events,
            row.landings,
            row.aggregates,
            row.flips,
            row.faults,
            row.checksum,
        );
    }
    let total = sim.total_events();
    let evs = total as f64 / ran.as_secs_f64().max(1e-9);
    println!(
        "built in {:.2}s, ran in {:.2}s ({} events, {:.0} events/s)",
        built.as_secs_f64(),
        ran.as_secs_f64(),
        total,
        evs,
    );

    if let Some(path) = csv {
        sim.write_csv(&path)?;
        println!("history written to {path}");
    }
    Ok(())
}
