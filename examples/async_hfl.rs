//! The asynchronous HFL engine end-to-end: the same hierarchy run under
//! the three synchronization modes of `hfl::async_engine` —
//! barrier-synchronized rounds, K-quorum semi-sync, and fully async
//! staleness-discounted aggregation — on one seed, for comparison.
//!
//! `cargo run --release --example async_hfl`

use anyhow::Result;
use arena::config::{ExperimentConfig, SyncModeCfg};
use arena::hfl::{AsyncHflEngine, RunHistory};

fn report(label: &str, hist: &RunHistory, p_bytes: usize, naive: usize) {
    println!("--- {label} ---");
    for r in &hist.rounds {
        let aggs: usize = r.gamma2.iter().sum();
        println!(
            "  k={:<3} t={:>7.1}s  acc {:.3}  E {:>7.2} mAh  edge-aggs {:>3}  \
             overlap {:.2}  link-util {:.2}  bufs {:>3}  share {:.2}",
            r.k,
            r.sim_now,
            r.accuracy,
            r.energy,
            aggs,
            r.comm_overlap_frac(),
            r.mean_link_util(),
            r.live_model_buffers,
            r.sharing_ratio
        );
    }
    println!(
        "  final acc {:.3}, total energy {:.1} mAh over {:.0}s",
        hist.final_accuracy(),
        hist.total_energy(),
        hist.total_time()
    );
    // The model-store win, measured: the resident (between-bursts) model
    // footprint vs one flat clone per cloud/edge/device handle. Peak
    // counts the training bursts too (N in-flight results genuinely
    // exist while devices train) — the win is the shared idle state.
    if let Some(last) = hist.rounds.last() {
        let live = last.live_model_buffers * p_bytes;
        println!(
            "  model memory: {} live buffers = {:.1} KiB resident \
             (peak {:.1} KiB) vs {:.1} KiB naive O(N*p) clones",
            last.live_model_buffers,
            live as f64 / 1024.0,
            last.peak_model_bytes as f64 / 1024.0,
            naive as f64 / 1024.0,
        );
    }
}

fn main() -> Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let dir = std::env::var("ARENA_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return Ok(());
    }
    let mut cfg = ExperimentConfig::mnist();
    cfg.topology.devices = 10;
    cfg.hfl.threshold_time = 700.0;
    cfg.sync.cloud_interval = 120.0;
    cfg.artifacts_dir = dir;

    // Synchronous through the event queue (identical to HflEngine).
    let mut sync_cfg = cfg.clone();
    sync_cfg.sync.mode = SyncModeCfg::Synchronous;
    let mut engine = AsyncHflEngine::new(sync_cfg, true)?;
    // One flat clone per cloud/edge/device model — the pre-store cost.
    let p_bytes = engine.eng.p * 4;
    let naive =
        (1 + cfg.topology.edges + cfg.topology.devices) * p_bytes;
    let hist = engine.run_to_threshold()?;
    report(
        "synchronous (event-driven barrier rounds)",
        &hist,
        p_bytes,
        naive,
    );

    // Semi-sync: edges close on a 2-report quorum, cloud on the timer.
    let mut semi_cfg = cfg.clone();
    semi_cfg.sync.mode = SyncModeCfg::SemiSync;
    semi_cfg.sync.quorum = 2;
    let mut engine = AsyncHflEngine::new(semi_cfg, true)?;
    let hist = engine.run_to_threshold()?;
    report(
        "semi-sync (K=2 quorum edges, cloud timer)",
        &hist,
        p_bytes,
        naive,
    );

    // Fully async with staleness discounting, plus device churn to show
    // stragglers/leavers no longer stall anyone. Uploads are in flight
    // while the next local round trains (see the overlap column); an
    // asymmetric uplink makes the contention visible.
    let mut async_cfg = cfg.clone();
    async_cfg.sync.mode = SyncModeCfg::Async;
    async_cfg.sync.staleness_alpha = 0.5;
    async_cfg.sim.leave_prob = 0.1;
    async_cfg.sim.join_prob = 0.5;
    async_cfg.link.up_bandwidth_scale = 0.5;
    async_cfg.link.contention = true;
    let mut engine = AsyncHflEngine::new(async_cfg, true)?;
    let hist = engine.run_to_threshold()?;
    report(
        "async (staleness-discounted, churning, narrow uplink)",
        &hist,
        p_bytes,
        naive,
    );

    println!("\nall three synchronization modes ran to the time threshold.");
    Ok(())
}
