//! Device mobility: devices join/leave between cloud rounds (paper §1,
//! §3.1 "if new devices join, the profiling module can also periodically
//! re-cluster"). Shows the engine tolerating a churning population.
//!
//! `cargo run --release --example mobility`

use anyhow::Result;
use arena::config::ExperimentConfig;
use arena::hfl::HflEngine;

fn main() -> Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let mut cfg = ExperimentConfig::mnist();
    cfg.topology.devices = 10;
    cfg.hfl.threshold_time = 800.0;
    // 15% leave / 50% rejoin per round — plain config knobs now (the CLI
    // equivalent: --set sim.leave_prob=0.15 --set sim.join_prob=0.5).
    cfg.sim.leave_prob = 0.15;
    cfg.sim.join_prob = 0.5;
    let mut engine = HflEngine::new(cfg.clone(), true)?;
    let m = engine.edges();
    while engine.remaining_time() > 0.0 {
        let active_before = engine.mobility.active_count();
        let stats = engine.run_round(&vec![3; m], &vec![2; m], None)?;
        let trained: usize = stats.per_edge.iter().map(|e| e.active).sum();
        println!(
            "round {:>2}: active {:>2}/{}  trained {:>2}  acc {:.3}  t={:.0}s",
            stats.k,
            active_before,
            cfg.topology.devices,
            trained,
            stats.accuracy,
            stats.sim_now
        );
    }
    println!("training survived churn; accuracy still improved.");
    Ok(())
}
