//! Churn-driven re-clustering walkthrough (paper §3.1: "if new devices
//! join, the profiling module can also periodically re-cluster").
//!
//! Devices join and leave mid-run; once the active set drifts past
//! `cluster.recluster_threshold`, the membership subsystem
//! (`hfl::membership`) re-profiles the live population, re-clusters it
//! region-constrained and balanced, and migrates the running topology —
//! each migrated device warm-starts from its new edge's current model,
//! delivered over that edge's downlink. Shown on both engines:
//!
//!  * the barrier engine re-clusters between cloud rounds;
//!  * the semi-sync event engine migrates *live* — in-flight training of
//!    moved devices is voided, quorums are re-derived from the new
//!    membership, and warm-start models ride real in-flight transfers.
//!
//! `cargo run --release --example churn_recluster`

use anyhow::Result;
use arena::config::{ExperimentConfig, SyncModeCfg};
use arena::hfl::{AsyncHflEngine, HflEngine};

fn main() -> Result<()> {
    std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "2");
    let dir = std::env::var("ARENA_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        return Ok(());
    }
    let mut cfg = ExperimentConfig::mnist();
    cfg.topology.devices = 10;
    cfg.hfl.threshold_time = 800.0;
    // 25% leave / 50% rejoin per interval, re-cluster at 10% drift but at
    // most once per 60 simulated seconds. CLI equivalent:
    //   --set sim.leave_prob=0.25 --set sim.join_prob=0.5 \
    //   --set cluster.recluster_threshold=0.1 \
    //   --set cluster.recluster_min_interval=60
    cfg.sim.leave_prob = 0.25;
    cfg.sim.join_prob = 0.5;
    cfg.cluster.recluster_threshold = 0.1;
    cfg.cluster.recluster_min_interval = 60.0;
    cfg.artifacts_dir = dir;

    println!("--- barrier engine: re-clustering between cloud rounds ---");
    let mut engine = HflEngine::new(cfg.clone(), true)?;
    let m = engine.edges();
    while engine.remaining_time() > 0.0 {
        let s = engine.run_round(&vec![3; m], &vec![2; m], None)?;
        println!(
            "round {:>2}: active {:>2}/{}  acc {:.3}  reclusters {}  \
             migrated {}  imbalance {:.2}",
            s.k,
            s.active_devices,
            cfg.topology.devices,
            s.accuracy,
            s.n_reclusters,
            s.migrated_devices,
            s.edge_size_imbalance
        );
        if s.n_reclusters > 0 {
            let out = engine.last_recluster.as_ref().unwrap();
            println!(
                "          -> re-clustered {} live devices at t={:.0}s \
                 (cluster mse {:.3}); {} moved, warm-start downlinks \
                 took {:.1}s",
                out.live,
                out.at,
                out.mse,
                out.migrated.len(),
                out.migration_downlink_time
            );
        }
    }

    println!("--- semi-sync event engine: live topology migration ---");
    let mut sc = cfg.clone();
    sc.sync.mode = SyncModeCfg::SemiSync;
    sc.sync.quorum = 2;
    sc.sync.cloud_interval = 120.0;
    let mut engine = AsyncHflEngine::new(sc, true)?;
    let hist = engine.run_to_threshold()?;
    for r in &hist.rounds {
        println!(
            "window {:>2}: t={:>6.1}s  acc {:.3}  active {:>2}  \
             reclusters {}  migrated {}",
            r.k,
            r.sim_now,
            r.accuracy,
            r.active_devices,
            r.n_reclusters,
            r.migrated_devices
        );
    }
    println!(
        "{} warm-start deliveries landed in flight; final acc {:.3}",
        engine.migration_log.len(),
        hist.final_accuracy()
    );
    println!("\nthe topology followed the churn; training never stopped.");
    Ok(())
}
