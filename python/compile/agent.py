"""L2: Arena's PPO actor-critic (paper §3.3-3.6) as jax fwd/bwd.

Network (paper §4.1: "2 convolutional layers and 3 fully connected layers
for the DRL network"): the (M+1) x (n_pca+3) state matrix (Fig. 6) goes
through two 3x3 SAME convolutions (1->8->16 channels), then fc->128->64,
then two heads: the actor head emits 4M values interpreted as 2M Gaussian
(mu, log_sigma) pairs — edge frequencies gamma_1^j and cloud frequencies
gamma_2^j per edge (paper §3.3) — and the critic head emits the value.

`ppo_update` is the clipped-surrogate PPO step (paper Eq. 13) with value
loss + entropy bonus, optimized with the fused Adam Pallas kernel. GAE
(Eq. 14) is computed on the rust side (scalar recursion over a trajectory)
and fed in as advantages/returns.

Dense layers go through the L1 tiled-matmul kernel; parameters are one
flat f32 vector like the device models.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul, optim, ref

CONV_CH = (8, 16)
FC = (128, 64)


def ppo_layout(m_edges, npca, extra=0):
    """[(name, shape, offset)] for the flat PPO parameter vector.

    `extra` appends state columns beyond the paper's npca+3 — the control
    layout (extra=5) carries per-edge staleness / in-flight / quorum-fill
    features plus the lifecycle observables (abandonment rate, diurnal
    availability) for the event-driven engine (rust: agent/state.rs
    `ctrl`).
    """
    rows, cols = m_edges + 1, npca + 3 + extra
    flat_dim = rows * cols * CONV_CH[1]
    n_act = 4 * m_edges
    shapes = [
        ("conv0_w", (3, 3, 1, CONV_CH[0])),
        ("conv0_b", (CONV_CH[0],)),
        ("conv1_w", (3, 3, CONV_CH[0], CONV_CH[1])),
        ("conv1_b", (CONV_CH[1],)),
        ("fc0_w", (flat_dim, FC[0])),
        ("fc0_b", (FC[0],)),
        ("fc1_w", (FC[0], FC[1])),
        ("fc1_b", (FC[1],)),
        ("actor_w", (FC[1], n_act)),
        ("actor_b", (n_act,)),
        ("critic_w", (FC[1], 1)),
        ("critic_b", (1,)),
    ]
    layout, off = [], 0
    for name, shape in shapes:
        n = 1
        for d in shape:
            n *= d
        layout.append((name, shape, off))
        off += n
    return layout


def ppo_param_count(m_edges, npca, extra=0):
    layout = ppo_layout(m_edges, npca, extra)
    name, shape, off = layout[-1]
    n = 1
    for d in shape:
        n *= d
    return off + n


def _unflatten(layout, flat):
    out = {}
    for name, shape, off in layout:
        n = 1
        for d in shape:
            n *= d
        out[name] = flat[off:off + n].reshape(shape)
    return out


def init_ppo_params(m_edges, npca, key, extra=0):
    """Orthogonal-ish (scaled normal) init, small actor head for stable mu."""
    parts = []
    for name, shape, _ in ppo_layout(m_edges, npca, extra):
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            parts.append(jnp.zeros(shape, jnp.float32).ravel())
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            scale = 0.01 if name.startswith(("actor", "critic")) else 1.0
            std = scale * jnp.sqrt(2.0 / fan_in)
            parts.append((jax.random.normal(sub, shape) * std)
                         .astype(jnp.float32).ravel())
    return jnp.concatenate(parts)


def _dense(x, w, b, act, use_pallas):
    if use_pallas:
        return matmul.dense(x, w, b, act)
    return ref.matmul_bias_act(x, w, b, activation=act)


def _conv3_same(x, w, b):
    """Tiny 3x3 SAME conv on the state image; [B,H,W,C]."""
    return jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + b


def forward(m_edges, npca, flat, states, use_pallas=True, extra=0):
    """states: [B, M+1, npca+3+extra] -> (mu[B,2M], sigma[B,2M], value[B])."""
    p = _unflatten(ppo_layout(m_edges, npca, extra), flat)
    h = states[..., None]  # [B, rows, cols, 1]
    h = jnp.maximum(_conv3_same(h, p["conv0_w"], p["conv0_b"]), 0.0)
    h = jnp.maximum(_conv3_same(h, p["conv1_w"], p["conv1_b"]), 0.0)
    h = h.reshape(h.shape[0], -1)
    h = _dense(h, p["fc0_w"], p["fc0_b"], "relu", use_pallas)
    h = _dense(h, p["fc1_w"], p["fc1_b"], "relu", use_pallas)
    a = _dense(h, p["actor_w"], p["actor_b"], "none", use_pallas)
    v = _dense(h, p["critic_w"], p["critic_b"], "none", use_pallas)
    n_act = 2 * m_edges
    mu = a[:, :n_act]
    log_sigma = jnp.clip(a[:, n_act:], -5.0, 2.0)
    return mu, jnp.exp(log_sigma), v[:, 0]


def _log_prob(mu, sigma, actions):
    """Diagonal Gaussian log density, summed over action dims."""
    z = (actions - mu) / sigma
    return jnp.sum(
        -0.5 * z * z - jnp.log(sigma) - 0.5 * jnp.log(2.0 * jnp.pi), axis=-1
    )


def _entropy(sigma):
    return jnp.sum(jnp.log(sigma) + 0.5 * jnp.log(2.0 * jnp.pi * jnp.e),
                   axis=-1)


def actor_fwd(m_edges, npca, use_pallas=True, extra=0):
    """Returns f(theta, state[M+1,cols]) -> (mu[2M], sigma[2M], value[1])."""

    def run(theta, state):
        mu, sigma, v = forward(m_edges, npca, theta, state[None],
                               use_pallas, extra)
        return mu[0], sigma[0], v

    return run


def ppo_update(m_edges, npca, lr=3e-4, clip_eps=0.2, vf_coef=0.5,
               ent_coef=0.01, use_pallas=True, extra=0):
    """Returns the PPO/Adam step function over a padded trajectory batch.

    f(theta, adam_m, adam_v, t[1],
      states[B,M+1,npca+3], actions[B,2M], old_logp[B],
      adv[B], ret[B], mask[B])
      -> (theta', m', v', losses[3]=(policy, value, entropy))
    """

    def loss(theta, states, actions, old_logp, adv, ret, mask):
        mu, sigma, values = forward(m_edges, npca, theta, states,
                                    use_pallas, extra)
        logp = _log_prob(mu, sigma, actions)
        ratio = jnp.exp(logp - old_logp)
        clipped = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps)
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        pol = -jnp.sum(jnp.minimum(ratio * adv, clipped * adv) * mask) / denom
        val = jnp.sum((values - ret) ** 2 * mask) / denom
        ent = jnp.sum(_entropy(sigma) * mask) / denom
        return pol + vf_coef * val - ent_coef * ent, (pol, val, ent)

    def step(theta, m, v, t, states, actions, old_logp, adv, ret, mask):
        (_, (pol, val, ent)), g = jax.value_and_grad(loss, has_aux=True)(
            theta, states, actions, old_logp, adv, ret, mask
        )
        if use_pallas:
            theta, m, v = optim.adam_step(theta, m, v, g, t[0], lr)
        else:
            theta, m, v = ref.adam_step(theta, m, v, g, t[0], lr)
        return theta, m, v, jnp.stack([pol, val, ent])

    return step
