"""L1 Pallas kernels (interpret=True on CPU) + pure-jnp oracles.

Every kernel here is the compute hot-spot of one piece of the Arena HFL
stack and lowers into the same HLO module as the L2 jax function that calls
it. Correctness is pinned against `ref.py` by `python/tests/test_kernels.py`
(hypothesis sweeps shapes), and the lowered HLO is executed from rust via
PJRT — python never runs on the request path.
"""

from . import fedavg, matmul, optim, ref  # noqa: F401
