"""Pallas kernel: tiled weighted model aggregation (paper Eq. 1/2).

This is the HFL hot loop — every edge aggregation reduces up to Nmax device
models of P parameters, and every cloud aggregation reduces M edge models.

TPU mapping: the parameter axis P is tiled into VMEM-sized blocks
(`BLOCK_P` f32 elements per model row); each grid step streams one
[N, BLOCK_P] tile HBM->VMEM and performs an [N]x[N,BLOCK_P] matvec on the
MXU/VPU. The weight vector is tiny and resident for all steps. Absent
models are encoded as weight 0, so one compiled artifact serves any
cluster size <= Nmax.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 16 KiB * Nmax per tile at Nmax=16 -> 1 MiB VMEM working set, far under
# the ~16 MiB VMEM budget; large enough to amortize grid overhead.
BLOCK_P = 4096


def _kernel(w_ref, m_ref, o_ref):
    # w_ref: [N] (whole, every step); m_ref: [N, bp]; o_ref: [bp]
    w = w_ref[...]
    wsum = jnp.sum(w)
    o_ref[...] = (w @ m_ref[...]) / wsum


@functools.partial(jax.jit, static_argnames=("block_p",))
def fedavg_reduce(models, weights, block_p=BLOCK_P):
    """Weighted aggregation of stacked flat models: [N,P],[N] -> [P]."""
    n, p = models.shape
    bp = min(block_p, p)
    pad = (-p) % bp
    if pad:
        models = jnp.pad(models, ((0, 0), (0, pad)))
    p_pad = p + pad
    out = pl.pallas_call(
        _kernel,
        grid=(p_pad // bp,),
        in_specs=[
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n, bp), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p_pad,), models.dtype),
        interpret=True,
    )(weights, models)
    return out[:p]
