"""Pallas kernels: fused elementwise optimizer updates on flat params.

`sgd_step` is applied after every local-training minibatch inside the
`train_epoch` scan; `adam_step` is the PPO agent update. Both tile the
flat parameter vector into VMEM blocks (pure VPU work, one HBM round trip
per tensor per step — already roofline for elementwise ops; block size
only amortizes grid overhead).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8192


def _pad_to(x, block):
    pad = (-x.shape[0]) % block
    return (jnp.pad(x, ((0, pad),)) if pad else x), x.shape[0] + pad


def _sgd_kernel(w_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("block",))
def sgd_step(w, g, lr, block=BLOCK):
    """w - lr * g over flat vectors; lr may be a python float or scalar."""
    p = w.shape[0]
    bp = min(block, p)
    wp, pp = _pad_to(w, bp)
    gp, _ = _pad_to(g, bp)
    lr_arr = jnp.asarray(lr, w.dtype).reshape(1)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), w.dtype),
        interpret=True,
    )(wp, gp, lr_arr)
    return out[:p]


def _adam_kernel(w_ref, m_ref, v_ref, g_ref, sc_ref, wo_ref, mo_ref, vo_ref, *, b1, b2, eps):
    lr, bc1, bc2 = sc_ref[0], sc_ref[1], sc_ref[2]
    g = g_ref[...]
    m_new = b1 * m_ref[...] + (1.0 - b1) * g
    v_new = b2 * v_ref[...] + (1.0 - b2) * g * g
    mhat = m_new / bc1
    vhat = v_new / bc2
    wo_ref[...] = w_ref[...] - lr * mhat / (jnp.sqrt(vhat) + eps)
    mo_ref[...] = m_new
    vo_ref[...] = v_new


@functools.partial(jax.jit, static_argnames=("b1", "b2", "eps", "block"))
def adam_step(w, m, v, g, t, lr, b1=0.9, b2=0.999, eps=1e-8, block=BLOCK):
    """Adam on flat vectors. t: 1-based step (scalar, f32). Returns (w,m,v)."""
    p = w.shape[0]
    bp = min(block, p)
    wp, pp = _pad_to(w, bp)
    mp, _ = _pad_to(m, bp)
    vp, _ = _pad_to(v, bp)
    gp, _ = _pad_to(g, bp)
    t = jnp.asarray(t, w.dtype)
    scalars = jnp.stack(
        [jnp.asarray(lr, w.dtype), 1.0 - b1**t, 1.0 - b2**t]
    )
    spec = pl.BlockSpec((bp,), lambda i: (i,))
    wo, mo, vo = pl.pallas_call(
        functools.partial(_adam_kernel, b1=b1, b2=b2, eps=eps),
        grid=(pp // bp,),
        in_specs=[spec, spec, spec, spec, pl.BlockSpec((3,), lambda i: (0,))],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((pp,), w.dtype)] * 3,
        interpret=True,
    )(wp, mp, vp, gp, scalars)
    return wo[:p], mo[:p], vo[:p]
