"""Pallas kernel: tiled fused dense layer activation(x @ w + b).

Used by the CNNs' fully-connected layers, the im2col-lowered convolutions,
the PPO actor-critic heads, and PCA projection (bias-free, no activation).

TPU mapping: classic (M,N,K)-tiled matmul. The grid iterates K innermost;
the output tile is revisited across K steps and used as the accumulator
(f32). Tiles default to 128x128x512, sized so x-tile + w-tile + o-tile
stay ~<1.5 MiB VMEM with MXU-aligned 128-lane shapes. Bias add and the
activation are fused into the last K step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 512


def _kernel(x_ref, w_ref, b_ref, o_ref, *, activation, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += x_ref[...] @ w_ref[...]

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        out = o_ref[...] + b_ref[...]
        if activation == "relu":
            out = jnp.maximum(out, 0.0)
        elif activation == "tanh":
            out = jnp.tanh(out)
        o_ref[...] = out


@functools.partial(
    jax.jit, static_argnames=("activation", "block_m", "block_n", "block_k")
)
def matmul_bias_act(
    x,
    w,
    b,
    activation="none",
    block_m=BLOCK_M,
    block_n=BLOCK_N,
    block_k=BLOCK_K,
):
    """Fused activation(x @ w + b); x:[M,K] w:[K,N] b:[N] -> [M,N]."""
    assert activation in ("none", "relu", "tanh"), activation
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    if pn:
        b = jnp.pad(b, ((0, pn),))
    mp, np_, kp = m + pm, n + pn, k + pk
    k_steps = kp // bk
    out = pl.pallas_call(
        functools.partial(_kernel, activation=activation, k_steps=k_steps),
        grid=(mp // bm, np_ // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
            pl.BlockSpec((bk, bn), lambda i, j, s: (s, j)),
            pl.BlockSpec((bn,), lambda i, j, s: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(x, w, b)
    return out[:m, :n]


# --------------------------------------------------------------------------
# Differentiable fused dense layer.
#
# pallas_call has no autodiff rule, so `dense` pins a custom VJP whose
# backward pass is ALSO two tiled-matmul kernel launches:
#   dx = dy @ w.T   and   dw = x.T @ dy   (db = colsum dy)
# keeping the entire fwd+bwd hot path on the L1 kernel.
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation="none"):
    """Differentiable activation(x @ w + b) on the Pallas matmul kernel."""
    return matmul_bias_act(x, w, b, activation)


def _dense_fwd(x, w, b, activation):
    y = matmul_bias_act(x, w, b, activation)
    return y, (x, w, y)


def _dense_bwd(activation, res, dy):
    x, w, y = res
    if activation == "relu":
        dy = dy * (y > 0.0).astype(dy.dtype)
    elif activation == "tanh":
        dy = dy * (1.0 - y * y)
    zero_k = jnp.zeros((w.shape[0],), x.dtype)
    zero_n = jnp.zeros((w.shape[1],), x.dtype)
    dx = matmul_bias_act(dy, w.T, zero_k)
    dw = matmul_bias_act(x.T, dy, zero_n)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


def pca_project(models, loadings):
    """PCA state projection (paper Eq. 6) as a bias-free tiled matmul."""
    r, p = models.shape
    npca = loadings.shape[1]
    zero = jnp.zeros((npca,), models.dtype)
    return matmul_bias_act(models, loadings, zero, activation="none")
