"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are tested against, and are also
selectable as the L2 compute path (`--kernels jnp` in aot.py) so kernel vs
reference can be A/B'd end-to-end from the rust side.
"""

import jax.numpy as jnp


def fedavg_reduce(models, weights):
    """Weighted aggregation (paper Eq. 1/2): sum_i w_i m_i / sum_i w_i.

    models: [N, P] stacked flattened models; weights: [N] (zeros = absent).
    """
    wsum = jnp.sum(weights)
    return (weights @ models) / wsum


def matmul_bias_act(x, w, b, activation="none"):
    """Fused dense layer: activation(x @ w + b). x:[M,K] w:[K,N] b:[N]."""
    out = x @ w + b
    if activation == "relu":
        out = jnp.maximum(out, 0.0)
    elif activation == "tanh":
        out = jnp.tanh(out)
    elif activation != "none":
        raise ValueError(f"unknown activation {activation}")
    return out


def sgd_step(w, g, lr):
    """Plain SGD update on flat parameter vectors."""
    return w - lr * g


def adam_step(w, m, v, g, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    """Adam update on flat parameter vectors. t is the 1-based step count."""
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * g * g
    mhat = m_new / (1.0 - b1**t)
    vhat = v_new / (1.0 - b2**t)
    w_new = w - lr * mhat / (jnp.sqrt(vhat) + eps)
    return w_new, m_new, v_new


def pca_project(models, loadings):
    """Project stacked flattened models onto PCA loading vectors.

    models: [R, P]; loadings: [P, npca] -> [R, npca]. (State s1, paper Eq. 6.)
    """
    return models @ loadings
