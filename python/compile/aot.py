"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/load_hlo/.

Outputs (under --out-dir, default ../artifacts):
  <name>.hlo.txt            one per artifact (see DESIGN.md §7)
  manifest.json             config constants + per-artifact I/O shapes +
                            flat-parameter layouts (validated by rust)
  init/<model>_params.bin   deterministic little-endian f32 initial params

Run via `make artifacts`; python never runs after this point.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import agent as agent_mod
from . import model as model_mod


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def lower(fn, specs):
    return to_hlo_text(jax.jit(fn).lower(*specs))


class Builder:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.artifacts = {}
        os.makedirs(out_dir, exist_ok=True)
        os.makedirs(os.path.join(out_dir, "init"), exist_ok=True)

    def emit(self, name, fn, in_specs, meta=None):
        text = lower(fn, in_specs)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *in_specs)
        flat_outs = jax.tree_util.tree_leaves(outs)
        self.artifacts[name] = {
            "file": fname,
            "inputs": [
                {"shape": list(s.shape), "dtype": str(s.dtype)}
                for s in in_specs
            ],
            "outputs": [
                {"shape": list(o.shape), "dtype": str(o.dtype)}
                for o in flat_outs
            ],
            **(meta or {}),
        }
        print(f"  {fname}: {len(text)} chars, "
              f"{len(in_specs)} in -> {len(flat_outs)} out")

    def write_init(self, name, flat):
        path = os.path.join(self.out_dir, "init", f"{name}_params.bin")
        np.asarray(flat, dtype="<f4").tofile(path)
        return f"init/{name}_params.bin"


def build_dataset(b, ds, cfg, use_pallas, train_pallas):
    arch = model_mod.ARCHS[ds]()
    p = model_mod.param_count(arch)
    h, w, c = arch["input"]
    nb, bs, ts = cfg["nb"], cfg["batch"], cfg["test_size"]
    lr = cfg["lr"][ds]
    layout = [
        {"name": n, "shape": list(s), "offset": o}
        for n, s, o in model_mod.param_layout(arch)
    ]

    b.emit(
        f"{ds}_train_epoch",
        model_mod.train_epoch(arch, lr, train_pallas),
        [spec([p]), spec([nb, bs, h, w, c]), spec([nb, bs], jnp.int32)],
        {"params": p, "lr": lr, "layout": layout},
    )
    b.emit(
        f"{ds}_eval",
        model_mod.evaluate(arch, chunk=cfg["eval_chunk"],
                           use_pallas=train_pallas),
        [spec([p]), spec([ts, h, w, c]), spec([ts], jnp.int32)],
        {"params": p},
    )
    b.emit(
        f"{ds}_aggregate",
        model_mod.aggregate(use_pallas),
        [spec([cfg["nmax"], p]), spec([cfg["nmax"]])],
        {"params": p},
    )
    b.emit(
        f"{ds}_pca_project",
        model_mod.pca_project(use_pallas),
        [spec([cfg["m_edges"] + 1, p]), spec([p, cfg["npca"]])],
        {"params": p},
    )

    key = jax.random.PRNGKey(cfg["seed"])
    init = model_mod.init_params(arch, key)
    assert init.shape[0] == p
    b.write_init(ds, init)
    return p


def build_agent(b, cfg, use_pallas, npca=None, datasets=(), ctrl=False):
    """Emit the PPO artifacts (and the matching pca_project variants) for
    one n_PCA value. npca=None uses the default (no name suffix); other
    values get an `_npca<k>` suffix — the Fig. 12 state-dimension ablation.
    ctrl=True emits the `_ctrl` variant instead: the extended
    (M+1) x (npca+8) control state whose per-edge rows carry the event
    engine's staleness / in-flight / quorum-fill features plus the
    lifecycle observables (abandonment rate, diurnal availability)
    (rust: agent/state.rs, decoded to per-edge (gamma1_j, alpha_j)).
    """
    m, bt = cfg["m_edges"], cfg["traj_batch"]
    default = npca is None
    npca = cfg["npca"] if default else npca
    assert not (ctrl and not default), "ctrl variant only at default n_PCA"
    extra = 5 if ctrl else 0
    suffix = "_ctrl" if ctrl else ("" if default else f"_npca{npca}")
    pp = agent_mod.ppo_param_count(m, npca, extra)
    rows, cols = m + 1, npca + 3 + extra

    b.emit(
        f"ppo_actor_fwd{suffix}",
        agent_mod.actor_fwd(m, npca, use_pallas, extra),
        [spec([pp]), spec([rows, cols])],
        {"params": pp, "npca": npca},
    )
    b.emit(
        f"ppo_update{suffix}",
        agent_mod.ppo_update(
            m, npca, lr=cfg["ppo_lr"], clip_eps=cfg["clip_eps"],
            use_pallas=use_pallas, extra=extra,
        ),
        [
            spec([pp]), spec([pp]), spec([pp]), spec([1]),
            spec([bt, rows, cols]), spec([bt, 2 * m]),
            spec([bt]), spec([bt]), spec([bt]), spec([bt]),
        ],
        {"params": pp, "lr": cfg["ppo_lr"], "npca": npca},
    )
    for ds in datasets:
        arch = model_mod.ARCHS[ds]()
        p = model_mod.param_count(arch)
        b.emit(
            f"{ds}_pca_project{suffix}",
            model_mod.pca_project(use_pallas),
            [spec([m + 1, p]), spec([p, npca])],
            {"params": p, "npca": npca},
        )

    key = jax.random.PRNGKey(cfg["seed"] + 1 + (7 if ctrl else 0))
    b.write_init(
        f"ppo{suffix}", agent_mod.init_ppo_params(m, npca, key, extra)
    )
    return pp


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--datasets", default="mnist,cifar")
    ap.add_argument(
        "--kernels", choices=["pallas", "hybrid", "jnp"], default="hybrid",
        help="L1 compute path. 'pallas' = kernels everywhere; 'hybrid' "
             "(default) = Pallas for the synchronization hot path "
             "(aggregate / pca_project / PPO) and the jnp oracle inside the "
             "device CNN epochs — interpret-mode Pallas costs ~15x on the "
             "1-core CI box (see EXPERIMENTS.md §Perf); 'jnp' = oracle "
             "everywhere (A/B reference)")
    ap.add_argument("--nb", type=int, default=2,
                    help="minibatches per local epoch (fixed artifact shape)")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--test-size", type=int, default=512)
    ap.add_argument("--eval-chunk", type=int, default=128)
    ap.add_argument("--m-edges", type=int, default=5)
    ap.add_argument("--npca", type=int, default=6)
    ap.add_argument("--nmax", type=int, default=16,
                    help="max devices per aggregation (weight-0 padding)")
    ap.add_argument("--traj-batch", type=int, default=32)
    ap.add_argument("--npca-variants", default="2,10",
                    help="extra n_PCA ablation variants (Fig. 12); '' = none")
    ap.add_argument("--ppo-lr", type=float, default=3e-4)
    ap.add_argument("--clip-eps", type=float, default=0.2)
    ap.add_argument("--lr-mnist", type=float, default=0.003)
    ap.add_argument("--lr-cifar", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=42)
    args = ap.parse_args()

    cfg = {
        "nb": args.nb, "batch": args.batch, "test_size": args.test_size,
        "eval_chunk": args.eval_chunk, "m_edges": args.m_edges,
        "npca": args.npca, "nmax": args.nmax, "traj_batch": args.traj_batch,
        "ppo_lr": args.ppo_lr, "clip_eps": args.clip_eps,
        "lr": {"mnist": args.lr_mnist, "cifar": args.lr_cifar},
        "seed": args.seed, "kernels": args.kernels,
    }
    use_pallas = args.kernels != "jnp"
    train_pallas = args.kernels == "pallas"
    b = Builder(args.out)

    datasets = [d for d in args.datasets.split(",") if d]
    params = {}
    for ds in datasets:
        print(f"lowering {ds} artifacts...")
        params[ds] = build_dataset(b, ds, cfg, use_pallas, train_pallas)
    print("lowering agent artifacts...")
    params["ppo"] = build_agent(b, cfg, use_pallas, datasets=())
    print("lowering control-state (ctrl) agent artifacts...")
    params["ppo_ctrl"] = build_agent(b, cfg, use_pallas, datasets=(),
                                     ctrl=True)
    for v in [v for v in args.npca_variants.split(",") if v]:
        k = int(v)
        print(f"lowering n_PCA={k} ablation artifacts...")
        params[f"ppo_npca{k}"] = build_agent(
            b, cfg, use_pallas, npca=k, datasets=datasets
        )

    manifest = {
        "config": cfg,
        "param_counts": params,
        "init": {k: f"init/{k}_params.bin" for k in params},
        "artifacts": b.artifacts,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(b.artifacts)} artifacts to {args.out}")


if __name__ == "__main__":
    main()
