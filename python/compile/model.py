"""L2: the paper's device-side models as jax fwd/bwd, calling L1 kernels.

Two CNNs, matching the paper §4.1:
  * MNIST-shape:  conv5x5x10 (VALID) -> pool -> conv5x5x20 (VALID) -> pool
                  -> fc 320->50 -> fc 50->10        = 21,840 params (exact)
  * CIFAR-shape:  conv5x5x32 (SAME) -> pool -> conv5x5x32 -> pool ->
                  conv5x5x64 -> pool -> fc 1024->328 -> fc 328->113
                  -> fc 113->10                     = 453,845 params
                  (paper: 453,834; +11 from integer layer sizing — the
                  closest 3conv+3fc factorization, see DESIGN.md)

Parameters live as ONE flat f32 vector so the rust coordinator can
aggregate / ship them as opaque buffers; the layout table (offsets+shapes)
is exported into artifacts/manifest.json.

Convolutions are lowered to im2col + the L1 tiled-matmul Pallas kernel, so
the training hot loop is kernel work. `train_epoch` scans `nb` minibatch
SGD steps in a single XLA program (one PJRT dispatch per local epoch).
"""

import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul, optim, ref


# --------------------------------------------------------------------------
# Architectures
# --------------------------------------------------------------------------

class ConvSpec:
    """conv k x k, cin -> cout, followed by 2x2 max pool."""

    def __init__(self, k, cin, cout, padding):
        self.k, self.cin, self.cout, self.padding = k, cin, cout, padding

    def shapes(self):
        return [((self.k, self.k, self.cin, self.cout), "w"),
                ((self.cout,), "b")]


class DenseSpec:
    def __init__(self, din, dout, act):
        self.din, self.dout, self.act = din, dout, act

    def shapes(self):
        return [((self.din, self.dout), "w"), ((self.dout,), "b")]


def mnist_arch():
    return {
        "name": "mnist",
        "input": (28, 28, 1),
        "convs": [ConvSpec(5, 1, 10, "VALID"), ConvSpec(5, 10, 20, "VALID")],
        "dense": [DenseSpec(320, 50, "relu"), DenseSpec(50, 10, "none")],
        "classes": 10,
    }


def cifar_arch():
    return {
        "name": "cifar",
        "input": (32, 32, 3),
        "convs": [
            ConvSpec(5, 3, 32, "SAME"),
            ConvSpec(5, 32, 32, "SAME"),
            ConvSpec(5, 32, 64, "SAME"),
        ],
        "dense": [
            DenseSpec(1024, 328, "relu"),
            DenseSpec(328, 113, "relu"),
            DenseSpec(113, 10, "none"),
        ],
        "classes": 10,
    }


ARCHS = {"mnist": mnist_arch, "cifar": cifar_arch}


def param_layout(arch) -> List[Tuple[str, Tuple[int, ...], int]]:
    """[(name, shape, offset)] for the flat parameter vector."""
    layout, off = [], 0
    for i, c in enumerate(arch["convs"]):
        for shape, kind in c.shapes():
            n = 1
            for d in shape:
                n *= d
            layout.append((f"conv{i}_{kind}", shape, off))
            off += n
    for i, d in enumerate(arch["dense"]):
        for shape, kind in d.shapes():
            n = 1
            for s in shape:
                n *= s
            layout.append((f"fc{i}_{kind}", shape, off))
            off += n
    return layout


def param_count(arch) -> int:
    layout = param_layout(arch)
    name, shape, off = layout[-1]
    n = 1
    for d in shape:
        n *= d
    return off + n


def unflatten(arch, flat):
    """Flat f32[P] -> list of parameter arrays in layout order."""
    out = []
    for _, shape, off in param_layout(arch):
        n = 1
        for d in shape:
            n *= d
        out.append(flat[off:off + n].reshape(shape))
    return out


def init_params(arch, key) -> jnp.ndarray:
    """He-initialized flat parameter vector."""
    parts = []
    for name, shape, _ in param_layout(arch):
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            parts.append(jnp.zeros(shape, jnp.float32).ravel())
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            std = jnp.sqrt(2.0 / fan_in)
            parts.append((jax.random.normal(sub, shape) * std)
                         .astype(jnp.float32).ravel())
    return jnp.concatenate(parts)


# --------------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------------

def _dense(x, w, b, act, use_pallas):
    if use_pallas:
        return matmul.dense(x, w, b, act)
    return ref.matmul_bias_act(x, w, b, activation=act)


def _im2col(x, k, padding):
    """[B,H,W,C] -> ([B*Ho*Wo, k*k*C], Ho, Wo) patch matrix (stride 1)."""
    b, h, w, c = x.shape
    if padding == "SAME":
        p = k // 2
        x = jnp.pad(x, ((0, 0), (p, p), (p, p), (0, 0)))
        ho, wo = h, w
    else:
        ho, wo = h - k + 1, w - k + 1
    # k*k static slices; stacks to [B,Ho,Wo,k*k,C] matching a row-major
    # (ki, kj, c) flatten of the [k,k,C,OC] filter.
    patches = jnp.stack(
        [x[:, i:i + ho, j:j + wo, :] for i in range(k) for j in range(k)],
        axis=3,
    )
    return patches.reshape(b * ho * wo, k * k * c), ho, wo


def _conv(x, wf, bf, spec, use_pallas):
    cols, ho, wo = _im2col(x, spec.k, spec.padding)
    wmat = wf.reshape(spec.k * spec.k * spec.cin, spec.cout)
    out = _dense(cols, wmat, bf, "relu", use_pallas)
    return out.reshape(x.shape[0], ho, wo, spec.cout)


def _maxpool2(x):
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def forward(arch, flat, x, use_pallas=True):
    """Logits for a batch x: [B, H, W, C] -> [B, classes]."""
    params = unflatten(arch, flat)
    i = 0
    h = x
    for spec in arch["convs"]:
        h = _conv(h, params[i], params[i + 1], spec, use_pallas)
        h = _maxpool2(h)
        i += 2
    h = h.reshape(h.shape[0], -1)
    for spec in arch["dense"]:
        h = _dense(h, params[i], params[i + 1], spec.act, use_pallas)
        i += 2
    return h


def loss_fn(arch, flat, x, y, use_pallas=True):
    """Mean softmax cross-entropy. y: int32 [B]."""
    logits = forward(arch, flat, x, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(y, arch["classes"], dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


# --------------------------------------------------------------------------
# AOT entry points
# --------------------------------------------------------------------------

def train_epoch(arch, lr, use_pallas=True):
    """Returns f(w, X[nb,B,H,W,C], Y[nb,B]) -> (w', mean_loss).

    One local-training epoch: lax.scan over nb minibatch SGD steps, each a
    grad step through the Pallas-kernel forward plus the fused sgd_step
    kernel. One PJRT dispatch per epoch on the rust side.
    """
    grad_fn = jax.value_and_grad(
        lambda w, x, y: loss_fn(arch, w, x, y, use_pallas)
    )

    def step(w, batch):
        x, y = batch
        loss, g = grad_fn(w, x, y)
        if use_pallas:
            w = optim.sgd_step(w, g, lr)
        else:
            w = ref.sgd_step(w, g, lr)
        return w, loss

    def epoch(w, xs, ys):
        w, losses = jax.lax.scan(step, w, (xs, ys))
        return w, jnp.mean(losses)

    return epoch


def evaluate(arch, chunk=128, use_pallas=True):
    """Returns f(w, Xt[T,H,W,C], Yt[T]) -> (correct_count, mean_loss).

    Scans the test set in fixed chunks to bound live memory. T must be a
    multiple of `chunk` (the aot config guarantees it).
    """

    def body(carry, batch):
        x, y = batch
        logits = forward(arch, carry["w"], x, use_pallas)
        pred = jnp.argmax(logits, axis=-1)
        logp = jax.nn.log_softmax(logits, axis=-1)
        onehot = jax.nn.one_hot(y, arch["classes"], dtype=logits.dtype)
        loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
        correct = jnp.sum((pred == y).astype(jnp.float32))
        return carry, (correct, loss)

    def run(w, xt, yt):
        t = xt.shape[0]
        n = t // chunk
        xs = xt.reshape((n, chunk) + xt.shape[1:])
        ys = yt.reshape((n, chunk))
        _, (cs, ls) = jax.lax.scan(body, {"w": w}, (xs, ys))
        return jnp.sum(cs), jnp.mean(ls)

    return run


def aggregate(use_pallas=True):
    """Returns f(models[Nmax,P], weights[Nmax]) -> w[P] (Eq. 1/2)."""
    if use_pallas:
        from .kernels import fedavg
        return lambda m, w: fedavg.fedavg_reduce(m, w)
    return lambda m, w: ref.fedavg_reduce(m, w)


def pca_project(use_pallas=True):
    """Returns f(models[R,P], loadings[P,npca]) -> [R,npca] (Eq. 6)."""
    if use_pallas:
        return lambda m, l: matmul.pca_project(m, l)
    return lambda m, l: ref.pca_project(m, l)
