"""L1 performance analysis: VMEM footprint + MXU-utilization estimates per
Pallas kernel configuration, and interpret-mode wallclock A/B against the
pure-jnp oracle.

interpret=True timings are CPU-numpy, NOT a TPU proxy — the optimization
object for L1 is the *structure* (block shapes vs VMEM, MXU tile
alignment); this tool makes that structure auditable, and the wallclock
A/B quantifies what the hybrid AOT mode (aot.py --kernels) trades.

Usage:  cd python && python -m compile.perf_report
"""

import time

import jax
import jax.numpy as jnp

from .kernels import fedavg, matmul, optim, ref

VMEM_BUDGET = 16 * 2**20  # ~16 MiB per TPU core
MXU = (128, 128)  # systolic array tile


def fmt_bytes(b):
    return f"{b / 2**10:.0f} KiB" if b < 2**20 else f"{b / 2**20:.2f} MiB"


def vmem_fedavg(nmax, block_p):
    """Per-grid-step VMEM: one [Nmax, bp] model tile + weights + out tile."""
    return 4 * (nmax * block_p + nmax + block_p)


def vmem_matmul(bm, bn, bk):
    """x-tile + w-tile + bias + out/accumulator tile."""
    return 4 * (bm * bk + bk * bn + bn + bm * bn)


def mxu_utilization(bm, bn, bk):
    """Fraction of the 128x128 MXU covered by the tile shape."""
    return min(bm / MXU[0], 1.0) * min(bn / MXU[1], 1.0)


def timeit(f, *args, reps=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def main():
    print("== L1 structure: VMEM footprint / MXU estimates ==")
    print(f"{'kernel':<28}{'tile':<20}{'VMEM/step':<12}{'MXU util':<10}ok?")
    for p, name in [(21_840, "mnist"), (453_845, "cifar")]:
        for bp in [1024, 4096, 8192, 16384]:
            v = vmem_fedavg(16, bp)
            print(f"fedavg_reduce/{name:<14}bp={bp:<15}{fmt_bytes(v):<12}"
                  f"{'n/a (matvec)':<10}"
                  f"{'yes' if v < VMEM_BUDGET else 'NO'}")
    for (bm, bn, bk) in [(32, 32, 64), (128, 128, 512), (256, 256, 512),
                         (512, 512, 1024)]:
        v = vmem_matmul(bm, bn, bk)
        u = mxu_utilization(bm, bn, bk)
        mark = "yes" if v < VMEM_BUDGET else "NO"
        print(f"{'matmul_bias_act':<28}{f'{bm}x{bn}x{bk}':<20}"
              f"{fmt_bytes(v):<12}{u:<10.2f}{mark}")

    print("\n== interpret-mode wallclock A/B (CPU; drives aot --kernels) ==")
    key = jax.random.PRNGKey(0)
    # fedavg at both model sizes
    for p, name in [(21_840, "mnist"), (453_845, "cifar")]:
        m = jax.random.normal(key, (16, p), dtype=jnp.float32)
        w = jnp.ones((16,))
        tp = timeit(jax.jit(fedavg.fedavg_reduce), m, w)
        tr = timeit(jax.jit(ref.fedavg_reduce), m, w)
        print(f"fedavg/{name}: pallas {tp * 1e3:8.2f} ms   "
              f"jnp {tr * 1e3:8.2f} ms   ratio {tp / tr:5.1f}x")
    # dense layer at CNN-ish shapes
    for (m_, k_, n_) in [(1152, 250, 10), (32, 320, 50), (512, 1024, 328)]:
        x = jax.random.normal(key, (m_, k_))
        wm = jax.random.normal(key, (k_, n_))
        b = jnp.zeros((n_,))
        f_p = jax.jit(lambda x, w, b: matmul.matmul_bias_act(x, w, b, "relu"))
        f_r = jax.jit(lambda x, w, b: ref.matmul_bias_act(x, w, b, "relu"))
        tp = timeit(f_p, x, wm, b)
        tr = timeit(f_r, x, wm, b)
        print(f"dense/{m_}x{k_}x{n_}: pallas {tp * 1e3:8.2f} ms   "
              f"jnp {tr * 1e3:8.2f} ms   ratio {tp / tr:5.1f}x")
    # optimizer step
    for p, name in [(21_840, "mnist"), (121_589, "ppo-adam")]:
        w = jax.random.normal(key, (p,))
        g = jax.random.normal(key, (p,))
        if name == "ppo-adam":
            m0 = jnp.zeros((p,))
            f_p = jax.jit(lambda w, g: optim.adam_step(w, m0, m0, g, 1.0, 1e-3))
            f_r = jax.jit(lambda w, g: ref.adam_step(w, m0, m0, g, 1.0, 1e-3))
        else:
            f_p = jax.jit(lambda w, g: optim.sgd_step(w, g, 0.01))
            f_r = jax.jit(lambda w, g: ref.sgd_step(w, g, 0.01))
        tp = timeit(f_p, w, g)
        tr = timeit(f_r, w, g)
        print(f"optim/{name}: pallas {tp * 1e3:8.2f} ms   "
              f"jnp {tr * 1e3:8.2f} ms   ratio {tp / tr:5.1f}x")


if __name__ == "__main__":
    main()
