"""PPO actor-critic checks: shapes, Gaussian math, update behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import agent as A

M_EDGES, NPCA = 5, 6
ROWS, COLS = M_EDGES + 1, NPCA + 3


def theta(seed=0):
    return A.init_ppo_params(M_EDGES, NPCA, jax.random.PRNGKey(seed))


def test_param_count_matches_layout():
    layout = A.ppo_layout(M_EDGES, NPCA)
    total = sum(int(np.prod(s)) for _, s, _ in layout)
    assert total == A.ppo_param_count(M_EDGES, NPCA)


def test_actor_fwd_shapes_and_ranges():
    th = theta()
    mu, sigma, v = A.actor_fwd(M_EDGES, NPCA)(th, jnp.ones((ROWS, COLS)))
    assert mu.shape == (2 * M_EDGES,)
    assert sigma.shape == (2 * M_EDGES,)
    assert v.shape == (1,)
    assert np.all(np.asarray(sigma) > 0), "sigma must be positive"
    # log_sigma clipped to [-5, 2]
    assert np.all(np.asarray(sigma) <= np.exp(2.0) + 1e-5)


def test_forward_batch_consistency():
    th = theta(1)
    states = jax.random.normal(jax.random.PRNGKey(2), (4, ROWS, COLS))
    mu_b, sigma_b, v_b = A.forward(M_EDGES, NPCA, th, states)
    for i in range(4):
        mu_i, sigma_i, v_i = A.forward(M_EDGES, NPCA, th, states[i:i + 1])
        np.testing.assert_allclose(mu_b[i], mu_i[0], rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(v_b[i], v_i[0], rtol=2e-4, atol=2e-4)


def test_log_prob_matches_scipy_formula():
    mu = jnp.zeros((1, 3))
    sigma = jnp.ones((1, 3)) * 2.0
    a = jnp.array([[1.0, -1.0, 0.5]])
    lp = A._log_prob(mu, sigma, a)
    want = np.sum(
        -0.5 * (np.asarray(a[0]) / 2.0) ** 2
        - np.log(2.0)
        - 0.5 * np.log(2 * np.pi)
    )
    np.testing.assert_allclose(lp[0], want, rtol=1e-5)


def test_entropy_increases_with_sigma():
    e1 = A._entropy(jnp.ones((1, 4)))
    e2 = A._entropy(2.0 * jnp.ones((1, 4)))
    assert float(e2[0]) > float(e1[0])


def _update_batch(B, seed=3):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    states = jax.random.normal(ks[0], (B, ROWS, COLS))
    actions = jax.random.normal(ks[1], (B, 2 * M_EDGES))
    old_logp = jax.random.normal(ks[2], (B,)) - 5.0
    adv = jax.random.normal(ks[3], (B,))
    ret = jax.random.normal(ks[4], (B,))
    mask = jnp.ones((B,))
    return states, actions, old_logp, adv, ret, mask


def test_ppo_update_changes_params_and_returns_losses():
    th = theta(2)
    B = 8
    m = jnp.zeros_like(th)
    v = jnp.zeros_like(th)
    upd = jax.jit(A.ppo_update(M_EDGES, NPCA))
    th2, m2, v2, losses = upd(th, m, v, jnp.ones((1,)), *_update_batch(B))
    assert th2.shape == th.shape
    assert not np.allclose(np.asarray(th2), np.asarray(th))
    assert losses.shape == (3,)
    assert np.all(np.isfinite(np.asarray(losses)))


def test_ppo_update_respects_mask():
    """Rows with mask 0 must not influence the update."""
    th = theta(4)
    m = jnp.zeros_like(th)
    v = jnp.zeros_like(th)
    upd = jax.jit(A.ppo_update(M_EDGES, NPCA))
    states, actions, old_logp, adv, ret, _ = _update_batch(8, seed=5)
    mask_half = jnp.array([1.0] * 4 + [0.0] * 4)
    out_half = upd(th, m, v, jnp.ones((1,)), states, actions, old_logp,
                   adv, ret, mask_half)
    # Same update with garbage in the masked rows:
    states2 = states.at[4:].set(999.0)
    ret2 = ret.at[4:].set(-999.0)
    out_garbage = upd(th, m, v, jnp.ones((1,)), states2, actions, old_logp,
                      adv, ret2, mask_half)
    np.testing.assert_allclose(np.asarray(out_half[0]),
                               np.asarray(out_garbage[0]),
                               rtol=1e-5, atol=1e-6)


def test_value_loss_decreases_with_repeated_updates():
    th = theta(6)
    m = jnp.zeros_like(th)
    v = jnp.zeros_like(th)
    upd = jax.jit(A.ppo_update(M_EDGES, NPCA, lr=1e-3))
    batch = _update_batch(16, seed=7)
    first = None
    for t in range(1, 40):
        th, m, v, losses = upd(th, m, v, jnp.full((1,), float(t)), *batch)
        if first is None:
            first = float(losses[1])
    assert float(losses[1]) < first, (float(losses[1]), first)


def test_ctrl_layout_extends_state_columns():
    # The control variant widens every state row by 5 feature columns
    # (staleness / in-flight / quorum fill / abandon rate / availability)
    # and grows fc0 accordingly, while the action head stays 2M wide.
    extra = 5
    layout = A.ppo_layout(M_EDGES, NPCA, extra)
    total = sum(int(np.prod(s)) for _, s, _ in layout)
    assert total == A.ppo_param_count(M_EDGES, NPCA, extra)
    assert total > A.ppo_param_count(M_EDGES, NPCA)
    th = A.init_ppo_params(M_EDGES, NPCA, jax.random.PRNGKey(3), extra)
    assert th.shape == (total,)
    state = jnp.ones((ROWS, COLS + extra))
    mu, sigma, v = A.actor_fwd(M_EDGES, NPCA, extra=extra)(th, state)
    assert mu.shape == (2 * M_EDGES,)
    assert sigma.shape == (2 * M_EDGES,)
    assert v.shape == (1,)
