"""AOT pipeline checks: HLO text is emitted, parses as HLO (sanity), and the
manifest agrees with the lowered shapes. Uses a tiny config to stay fast."""

import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
PYDIR = os.path.join(REPO, "python")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [
            sys.executable, "-m", "compile.aot",
            "--out", str(out),
            "--datasets", "mnist",
            "--nb", "2", "--batch", "8", "--test-size", "64",
            "--eval-chunk", "32", "--traj-batch", "8",
        ],
        cwd=PYDIR,
        check=True,
        capture_output=True,
    )
    return out


def test_emits_all_mnist_and_agent_artifacts(built):
    names = {
        "mnist_train_epoch", "mnist_eval", "mnist_aggregate",
        "mnist_pca_project", "ppo_actor_fwd", "ppo_update",
    }
    for n in names:
        path = built / f"{n}.hlo.txt"
        assert path.exists(), n
        text = path.read_text()
        assert text.startswith("HloModule"), n
        assert "ENTRY" in text, n


def test_manifest_consistent(built):
    man = json.loads((built / "manifest.json").read_text())
    assert man["param_counts"]["mnist"] == 21840
    arts = man["artifacts"]
    te = arts["mnist_train_epoch"]
    assert te["inputs"][0]["shape"] == [21840]
    assert te["inputs"][1]["shape"] == [2, 8, 28, 28, 1]
    assert te["inputs"][2]["dtype"] == "int32"
    assert len(te["outputs"]) == 2
    up = arts["ppo_update"]
    assert len(up["inputs"]) == 10
    assert up["inputs"][4]["shape"] == [8, 6, 9]


def test_init_params_binary_sized(built):
    man = json.loads((built / "manifest.json").read_text())
    p = man["param_counts"]["mnist"]
    size = (built / "init" / "mnist_params.bin").stat().st_size
    assert size == 4 * p
    pp = man["param_counts"]["ppo"]
    size = (built / "init" / "ppo_params.bin").stat().st_size
    assert size == 4 * pp


def test_layout_in_manifest_covers_all_params(built):
    man = json.loads((built / "manifest.json").read_text())
    layout = man["artifacts"]["mnist_train_epoch"]["layout"]
    total = 0
    for entry in layout:
        n = 1
        for d in entry["shape"]:
            n *= d
        assert entry["offset"] == total
        total += n
    assert total == man["param_counts"]["mnist"]
