"""Kernel vs oracle: the CORE L1 correctness signal.

hypothesis sweeps shapes/values; every Pallas kernel must match its pure-jnp
reference to float32 tolerance, including the ragged (non-multiple-of-block)
edges the wrappers pad away.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fedavg, matmul, optim, ref

SET = dict(max_examples=25, deadline=None)


def rand(key, shape, lo=-2.0, hi=2.0):
    return jax.random.uniform(jax.random.PRNGKey(key), shape,
                              minval=lo, maxval=hi, dtype=jnp.float32)


# --------------------------------------------------------------------------
# fedavg_reduce
# --------------------------------------------------------------------------

@settings(**SET)
@given(n=st.integers(1, 16), p=st.integers(1, 9000), seed=st.integers(0, 99))
def test_fedavg_matches_ref(n, p, seed):
    models = rand(seed, (n, p))
    weights = rand(seed + 1, (n,), lo=0.0, hi=5.0) + 0.01
    got = fedavg.fedavg_reduce(models, weights, block_p=2048)
    want = ref.fedavg_reduce(models, weights)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_fedavg_zero_weight_models_are_ignored():
    models = jnp.stack([jnp.ones(100), 5.0 * jnp.ones(100),
                        999.0 * jnp.ones(100)])
    weights = jnp.array([1.0, 3.0, 0.0])
    got = fedavg.fedavg_reduce(models, weights)
    np.testing.assert_allclose(got, jnp.full(100, 4.0), rtol=1e-6)


def test_fedavg_single_model_identity():
    m = rand(7, (1, 500))
    got = fedavg.fedavg_reduce(m, jnp.ones((1,)))
    np.testing.assert_allclose(got, m[0], rtol=1e-6)


def test_fedavg_equal_weights_is_mean():
    m = rand(8, (4, 300))
    got = fedavg.fedavg_reduce(m, jnp.ones((4,)))
    np.testing.assert_allclose(got, m.mean(axis=0), rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# matmul_bias_act / dense
# --------------------------------------------------------------------------

@settings(**SET)
@given(
    m=st.integers(1, 100),
    k=st.integers(1, 300),
    n=st.integers(1, 80),
    act=st.sampled_from(["none", "relu", "tanh"]),
    seed=st.integers(0, 99),
)
def test_matmul_matches_ref(m, k, n, act, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    b = rand(seed + 2, (n,))
    got = matmul.matmul_bias_act(x, w, b, activation=act,
                                 block_m=32, block_n=32, block_k=64)
    want = ref.matmul_bias_act(x, w, b, activation=act)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_matmul_blocks_larger_than_problem():
    x, w, b = rand(1, (3, 5)), rand(2, (5, 2)), rand(3, (2,))
    got = matmul.matmul_bias_act(x, w, b)
    np.testing.assert_allclose(got, ref.matmul_bias_act(x, w, b),
                               rtol=1e-5, atol=1e-6)


@settings(**SET)
@given(
    m=st.integers(2, 40),
    k=st.integers(2, 60),
    n=st.integers(1, 30),
    act=st.sampled_from(["none", "relu", "tanh"]),
    seed=st.integers(0, 99),
)
def test_dense_gradients_match_ref(m, k, n, act, seed):
    """custom_vjp backward (Pallas both ways) == autodiff of the oracle."""
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    b = rand(seed + 2, (n,))
    dy = rand(seed + 3, (m, n))

    def f_pallas(x, w, b):
        return jnp.sum(matmul.dense(x, w, b, act) * dy)

    def f_ref(x, w, b):
        return jnp.sum(ref.matmul_bias_act(x, w, b, activation=act) * dy)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=5e-4, atol=5e-4)


# --------------------------------------------------------------------------
# optimizers
# --------------------------------------------------------------------------

@settings(**SET)
@given(p=st.integers(1, 20000), seed=st.integers(0, 99))
def test_sgd_matches_ref(p, seed):
    w, g = rand(seed, (p,)), rand(seed + 1, (p,))
    got = optim.sgd_step(w, g, 0.01, block=4096)
    np.testing.assert_allclose(got, ref.sgd_step(w, g, 0.01),
                               rtol=1e-6, atol=1e-7)


@settings(**SET)
@given(p=st.integers(1, 20000), t=st.integers(1, 100),
       seed=st.integers(0, 99))
def test_adam_matches_ref(p, t, seed):
    w, g = rand(seed, (p,)), rand(seed + 1, (p,))
    m = rand(seed + 2, (p,), lo=-0.5, hi=0.5)
    v = rand(seed + 3, (p,), lo=0.0, hi=0.5)
    got = optim.adam_step(w, m, v, g, float(t), 1e-3, block=4096)
    want = ref.adam_step(w, m, v, g, float(t), 1e-3)
    for a, e in zip(got, want):
        np.testing.assert_allclose(a, e, rtol=2e-5, atol=2e-6)


def test_adam_zero_grad_keeps_moments_decaying():
    p = 64
    w = rand(1, (p,))
    m = jnp.ones((p,))
    v = jnp.ones((p,))
    g = jnp.zeros((p,))
    w2, m2, v2 = optim.adam_step(w, m, v, g, 5.0, 1e-3)
    np.testing.assert_allclose(m2, 0.9 * m, rtol=1e-6)
    np.testing.assert_allclose(v2, 0.999 * v, rtol=1e-6)
    assert not np.allclose(w2, w)  # nonzero moments still move w


# --------------------------------------------------------------------------
# pca projection
# --------------------------------------------------------------------------

@settings(**SET)
@given(r=st.integers(1, 8), p=st.integers(1, 4000),
       npca=st.integers(1, 10), seed=st.integers(0, 99))
def test_pca_project_matches_ref(r, p, npca, seed):
    models = rand(seed, (r, p))
    loadings = rand(seed + 1, (p, npca))
    got = matmul.pca_project(models, loadings)
    want = ref.pca_project(models, loadings)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
