"""L2 model checks: param counts (paper §4.1), shapes, learning signal,
pallas-vs-jnp forward equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def make_batch(arch, nb, bs, seed=0):
    h, w, c = arch["input"]
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (nb, bs, h, w, c), dtype=jnp.float32)
    y = jax.random.randint(ky, (nb, bs), 0, arch["classes"])
    return x, y


def test_mnist_param_count_matches_paper_exactly():
    assert M.param_count(M.mnist_arch()) == 21840


def test_cifar_param_count_close_to_paper():
    # Paper: 453,834; closest 3conv+3fc integer factorization is +11.
    got = M.param_count(M.cifar_arch())
    assert abs(got - 453834) <= 16, got


def test_layout_is_contiguous_and_ordered():
    for name in ("mnist", "cifar"):
        arch = M.ARCHS[name]()
        off = 0
        for pname, shape, offset in M.param_layout(arch):
            assert offset == off, (pname, offset, off)
            n = int(np.prod(shape))
            off += n
        assert off == M.param_count(arch)


def test_unflatten_round_trips():
    arch = M.mnist_arch()
    flat = jnp.arange(M.param_count(arch), dtype=jnp.float32)
    parts = M.unflatten(arch, flat)
    re = jnp.concatenate([p.ravel() for p in parts])
    np.testing.assert_array_equal(re, flat)


def test_forward_shapes():
    arch = M.mnist_arch()
    w = M.init_params(arch, jax.random.PRNGKey(0))
    x, _ = make_batch(arch, 1, 8)
    logits = M.forward(arch, w, x[0])
    assert logits.shape == (8, 10)


def test_forward_pallas_matches_jnp_path():
    arch = M.mnist_arch()
    w = M.init_params(arch, jax.random.PRNGKey(1))
    x, _ = make_batch(arch, 1, 4, seed=3)
    lp = M.forward(arch, w, x[0], use_pallas=True)
    lr = M.forward(arch, w, x[0], use_pallas=False)
    np.testing.assert_allclose(lp, lr, rtol=2e-4, atol=2e-4)


def test_train_epoch_reduces_loss():
    arch = M.mnist_arch()
    w = M.init_params(arch, jax.random.PRNGKey(2))
    x, y = make_batch(arch, 2, 32, seed=5)
    ep = jax.jit(M.train_epoch(arch, 0.01))
    losses = []
    for _ in range(4):
        w, loss = ep(w, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


def test_train_epoch_pallas_matches_jnp_path():
    arch = M.mnist_arch()
    w = M.init_params(arch, jax.random.PRNGKey(4))
    x, y = make_batch(arch, 2, 16, seed=6)
    wp, lp = jax.jit(M.train_epoch(arch, 0.01, use_pallas=True))(w, x, y)
    wr, lr = jax.jit(M.train_epoch(arch, 0.01, use_pallas=False))(w, x, y)
    assert abs(float(lp) - float(lr)) < 1e-3
    np.testing.assert_allclose(wp, wr, rtol=5e-3, atol=5e-4)


def test_evaluate_counts_correct():
    arch = M.mnist_arch()
    w = M.init_params(arch, jax.random.PRNGKey(3))
    h, wd, c = arch["input"]
    xt = jax.random.normal(jax.random.PRNGKey(9), (128, h, wd, c))
    ev = jax.jit(M.evaluate(arch, chunk=64))
    # consistent with argmax of forward
    logits = M.forward(arch, w, xt)
    pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    correct, _ = ev(w, xt, pred)
    assert float(correct) == 128.0
    wrong = (pred + 1) % 10
    correct, _ = ev(w, xt, wrong)
    assert float(correct) == 0.0


def test_aggregate_entry_point():
    agg = M.aggregate(use_pallas=True)
    models = jnp.stack([jnp.full(50, 2.0), jnp.full(50, 6.0)])
    out = agg(models, jnp.array([1.0, 1.0]))
    np.testing.assert_allclose(out, jnp.full(50, 4.0), rtol=1e-6)


def test_overfits_tiny_learnable_dataset():
    """End-to-end learnability: class-dependent means must become separable."""
    arch = M.mnist_arch()
    w = M.init_params(arch, jax.random.PRNGKey(7))
    h, wd, c = arch["input"]
    key = jax.random.PRNGKey(8)
    y = jnp.tile(jnp.arange(4, dtype=jnp.int32), 8)  # 32 samples, 4 classes
    protos = jax.random.normal(key, (10, h, wd, c)) * 2.0
    x = protos[y] + 0.1 * jax.random.normal(key, (32, h, wd, c))
    xs = x[None]
    ys = y[None]
    ep = jax.jit(M.train_epoch(arch, 0.05))
    for _ in range(30):
        w, loss = ep(w, xs, ys)
    logits = M.forward(arch, w, x)
    acc = float(jnp.mean((jnp.argmax(logits, -1) == y)))
    assert acc > 0.9, f"acc={acc}, loss={float(loss)}"
